"""Per-attempt waste/retry arithmetic shared by the serial replay and the
event-driven cluster engine (paper §III-A semantics, one source of truth).

The serial simulator runs a task to completion in one tight loop; the
cluster engine interleaves attempts of many tasks across an event queue.
Both step the same ``AttemptLedger`` state machine, so the two paths
cannot drift apart:

  * a killed attempt burns its whole allocation for ``ttf * runtime``;
  * a successful attempt wastes ``(allocation - actual) * runtime`` GBh;
  * retries follow the method's own policy, clamped to the machine/node
    capacity; a task is aborted once even the capacity fails or the
    ``MAX_ATTEMPTS`` safety valve trips;
  * a *preempted* or *crash-killed* attempt (heterogeneous cluster engine)
    burns only the partial reservation it held — it is an interruption,
    not an OOM failure: no failure count, no retry-ladder step, no abort
    pressure.

Failure-handling strategies (Ponder-style, arXiv 2408.00047) change what
an *interruption* costs — OOM arithmetic is identical under every
strategy, so the sizing comparison stays apples-to-apples:

  * ``retry_same`` (default, the pre-strategy semantics): the killed
    attempt burns its whole partial reservation and re-runs from scratch
    under the same reservation;
  * ``retry_scaled``: same burn arithmetic, but the engine re-sizes the
    attempt through the method before re-dispatch (``refresh_pending``),
    so a tightened prediction shrinks what the next crash can burn;
  * ``checkpoint``: the attempt checkpoints every ``checkpoint_frac`` of
    its runtime; a crash burns the full reservation only for the work
    since the last checkpoint (``interruption_gbh``) and the mere
    *headroom* for the retained prefix, and the re-run executes only the
    remaining ``1 - completed_frac`` of the task. Retention applies to
    attempts that would have succeeded (a doomed attempt was running
    over-limit — its "progress" is an artifact, so it burns in full, and
    an OOM kill always restarts from scratch: the bigger-allocation rerun
    re-executes everything). A *temporal* (multi-segment-plan) attempt
    retains up to the last plan segment boundary it completed: the plan
    survives the interruption and the re-run resumes the reservation
    schedule from that boundary (``start_alloc_gb`` is the plan value
    there, RESIZE events cover only the remaining boundaries) instead of
    re-running — and re-burning — the whole plan from segment 0.

Every ledger splits its waste by *cause*: ``oom_gbh`` (burned by OOM
kills) + ``interruption_gbh`` (burned by crashes/preemptions, the truly
lost reservation) + implicit headroom (``wastage_gbh`` minus both), so
interruption vs OOM waste is attributable per failure-handling strategy.

Straggler injection stretches an attempt in *time*: ``slowdown >= 1``
multiplies the attempt's wall duration and therefore every reservation
time-integral (the usage curve stretches with it — the same work takes
longer). ``slowdown`` is per-attempt state set by the engine at dispatch;
1.0 (the default, and always the serial replay's value) is arithmetically
inert: multiplying by 1.0 is exact in IEEE-754, so failure-free traces
stay bitwise-identical.

Temporal attempts (KS+-style time-segmented allocators) extend the state
machine without touching the legacy arithmetic:

  * a :class:`~repro.core.temporal.segments.ReservationPlan` with >= 2
    segments makes the attempt *temporal*: the reservation follows the
    plan (the engines resize at segment boundaries) and success requires
    the plan to cover the task's ground-truth ``usage_curve`` at every
    time, not just its peak;
  * a temporal OOM kill happens at the curve's first crossing of the plan
    (the violation time IS the time-to-failure, so ``ttf`` does not scale
    it) and burns the plan's partial reservation integral;
  * a plan with ONE segment is a constant reservation — it is executed on
    the legacy peak path, arithmetic bitwise-identical to a plain
    allocation (the resize-disabled / k=1 configuration);
  * retries after any failure fall back to a FLAT reservation from the
    method's ladder (after an OOM you size conservatively), as do plans
    that failed to grow ``MAX_GROW_FAILURES`` times on a busy node.

Every ledger additionally tracks **time-integrated waste** ``tw_gbh``:
integral of (reserved(t) - used(t)) over the attempt, using the task's
usage curve (flat at the peak when the trace carries none — in which case
``tw_gbh == wastage_gbh`` exactly). Peak and temporal allocators therefore
plot on one Fig. 8-style GB·h axis.

``cap_gb`` is per-ledger: the serial replay passes the machine capacity
(or the task's own ``machine_cap_gb`` when the trace is heterogeneous),
the cluster engine the capacity of the *largest node the task could ever
be placed on* — so clamp/abort semantics follow the hardware the task can
actually reach, not a global constant.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.temporal.segments import ReservationPlan
from repro.workflow.trace import TaskInstance

MAX_ATTEMPTS = 16  # safety valve; the doubling ladder reaches any cap first

# Ponder-style failure-handling strategies (see module docstring): how an
# interrupted (crashed/preempted) attempt is charged and re-run
FAILURE_STRATEGIES = ("retry_same", "retry_scaled", "checkpoint")

# checkpoint cadence of the "checkpoint" strategy: one checkpoint every
# this fraction of the task's runtime (methods may override via a
# ``checkpoint_frac`` attribute)
DEFAULT_CHECKPOINT_FRAC = 0.25

# after this many failed reservation *grows* (node too full at a segment
# boundary) the plan flattens to a constant peak reservation — placement
# then serializes the task like any peak attempt, guaranteeing progress
MAX_GROW_FAILURES = 3


def doubling_retry(last_alloc_gb: float, cap_gb: float) -> float:
    """The standard resource-manager failure ladder: double, clamp to cap."""
    return min(last_alloc_gb * 2.0, cap_gb)


@dataclasses.dataclass
class TaskOutcome:
    task: TaskInstance
    first_alloc_gb: float
    final_alloc_gb: float
    attempts: int
    failures: int
    wastage_gbh: float
    runtime_h: float            # wall time incl. failed attempts
    aborted: bool = False
    interruptions: int = 0      # preemptions / node-crash kills (not OOMs)
    # time-integrated waste: integral of reserved-minus-used GB·h over the
    # task's attempts (== wastage_gbh when the trace carries no usage
    # curves). The one axis peak and temporal allocators share.
    tw_gbh: float = 0.0
    grow_failures: int = 0      # denied reservation grows (temporal plans)
    # waste attribution by cause (oom + interruption + headroom == total):
    # OOM kills burn oom_gbh, crash/preemption kills burn interruption_gbh
    # (under "checkpoint" only the since-last-checkpoint loss counts here),
    # the rest of wastage_gbh is over-provisioning headroom
    oom_gbh: float = 0.0
    interruption_gbh: float = 0.0
    # event timestamps (filled by the simulators; serial replay uses a
    # running clock, the cluster engine real event times)
    submit_h: float = 0.0       # became ready / was submitted
    start_h: float = 0.0        # first attempt dispatched
    finish_h: float = 0.0       # completed or aborted

    @property
    def queue_delay_h(self) -> float:
        return self.start_h - self.submit_h


@dataclasses.dataclass
class AttemptLedger:
    """Mutable per-task attempt state, stepped identically by both engines."""
    task: TaskInstance
    first_alloc_gb: float
    cap_gb: float               # machine (serial) or node (cluster) capacity
    ttf: float
    alloc_gb: float = dataclasses.field(init=False)
    attempts: int = 1
    failures: int = 0
    wastage_gbh: float = 0.0
    runtime_h: float = 0.0
    aborted: bool = False
    interruptions: int = 0
    tw_gbh: float = 0.0
    # temporal state: the reservation plan of the CURRENT attempt (None =
    # flat legacy reservation at alloc_gb)
    plan: ReservationPlan | None = None
    grow_failures: int = 0
    # failure-handling strategy of this task's interruptions (engine passes
    # the method's choice; the serial replay never interrupts, so the
    # default is inert there)
    failure_strategy: str = "retry_same"
    checkpoint_frac: float = DEFAULT_CHECKPOINT_FRAC
    # work retained from checkpoints: the re-run executes [completed_frac,1]
    completed_frac: float = 0.0
    # straggler stretch of the CURRENT attempt's wall time (>= 1.0; set by
    # the engine at dispatch, reset to 1.0 for every new dispatch)
    slowdown: float = 1.0
    # waste attribution by cause (see TaskOutcome)
    oom_gbh: float = 0.0
    interruption_gbh: float = 0.0
    # retry_scaled: set after an interruption; the engine re-sizes the task
    # through the method before the next dispatch, then clears it
    refresh_pending: bool = False

    def __post_init__(self):
        self.alloc_gb = self.first_alloc_gb
        self._violation: float | None | bool = False  # False = not computed
        if self.failure_strategy not in FAILURE_STRATEGIES:
            raise ValueError(
                f"unknown failure strategy {self.failure_strategy!r} "
                f"(have {FAILURE_STRATEGIES})")

    # ------------------------------------------------------------ temporal
    def set_plan(self, plan: ReservationPlan | None) -> None:
        """Attach a reservation plan to the current attempt. Single-segment
        plans are a constant reservation == the legacy path; they are
        dropped here so every downstream branch sees ``temporal_active ==
        False`` and the arithmetic stays bitwise-identical to a plain
        allocation (the k=1 acceptance invariant)."""
        if plan is not None:
            plan = plan.simplify()
            if plan.k <= 1:
                plan = None
        self.plan = plan
        self._violation = False

    @property
    def temporal_active(self) -> bool:
        return self.plan is not None

    @property
    def start_alloc_gb(self) -> float:
        """What dispatch actually reserves: the plan's value at the resume
        point for a temporal attempt (its FIRST segment when nothing is
        retained — checkpoint retention resumes mid-plan), the flat
        allocation otherwise."""
        if self.plan is not None:
            if self.completed_frac > 0.0:
                return self.plan.value_at(self.completed_frac)
            return self.plan.start_gb
        return self.alloc_gb

    @property
    def violation_frac(self) -> float | None:
        """First runtime fraction where usage exceeds the plan (None =
        the plan covers the whole curve). An empty ``usage_curve`` means
        "flat at the peak" (legacy trace semantics), so a plan must cover
        ``actual_peak_gb`` for the whole runtime there — a multi-segment
        plan can never dodge an OOM just because the trace carries no
        time-resolved ground truth. Cached per attempt."""
        if self._violation is False:
            if self.plan is None:
                self._violation = None
            else:
                curve = (self.task.usage_curve
                         or ((1.0, self.task.actual_peak_gb),))
                self._violation = self.plan.first_violation(curve)
        return self._violation

    def _reserved_gbh(self, upto_frac: float, frm: float = 0.0) -> float:
        """GB·h reserved over the ``[frm, upto_frac]`` window of the
        (straggler-stretched) runtime under the current attempt's
        reservation (plan or flat). Fractions are of *nominal* runtime; a
        straggler holds the same reservation ``slowdown`` times longer in
        wall time. ``frm > 0`` is the mid-plan resume window (a retained
        attempt never re-reserves its completed prefix)."""
        if self.plan is not None:
            gbh = self.plan.gbh(self.task.runtime_h, upto_frac)
            if frm > 0.0:
                gbh -= self.plan.gbh(self.task.runtime_h, frm)
            return gbh * self.slowdown
        if frm > 0.0:
            return self.alloc_gb * (upto_frac - frm) * self.task.runtime_h \
                * self.slowdown
        return self.alloc_gb * upto_frac * self.task.runtime_h \
            * self.slowdown

    # ----------------------------------------------------- engine controls
    def set_slowdown(self, slowdown: float) -> None:
        """Straggler stretch for the attempt about to dispatch (>= 1)."""
        if slowdown < 1.0:
            raise ValueError(f"slowdown must be >= 1, got {slowdown}")
        self.slowdown = slowdown

    def refresh_alloc(self, alloc_gb: float) -> float:
        """retry_scaled re-size after an interruption: the method's fresh
        allocation replaces the current one (clamped to capacity) WITHOUT
        an attempt/ladder step — the crash was not the sizing's fault. Any
        plan is dropped (the re-run is flat). Clears ``refresh_pending``."""
        self.alloc_gb = min(float(alloc_gb), self.cap_gb)
        self.plan = None
        self._violation = False
        self.refresh_pending = False
        return self.alloc_gb

    # ------------------------------------------------------------- queries
    @property
    def will_succeed(self) -> bool:
        """Strict limits (assumption A3): the attempt survives iff the
        reservation covers the ground-truth usage — the peak for a flat
        attempt, the whole curve for a temporal one."""
        if self.plan is not None:
            return self.violation_frac is None
        return self.alloc_gb >= self.task.actual_peak_gb

    @property
    def attempt_duration_h(self) -> float:
        """Wall time of the *next* attempt: full (remaining) runtime on
        success. A flat attempt that will OOM runs for the ttf-scaled
        prefix (the paper's simulation parameter); a temporal attempt dies
        exactly at the curve's first crossing of the plan (the violation
        time IS the time-to-failure, so ttf does not apply). A straggler
        attempt stretches by ``slowdown``; checkpoint retention shrinks a
        succeeding re-run to the un-retained suffix."""
        if self.will_succeed:
            return self.task.runtime_h * self.slowdown \
                * (1.0 - self.completed_frac)
        if self.plan is not None:
            # a resumed plan runs [completed_frac, violation]; cf == 0.0
            # keeps the subtraction bitwise-inert
            return max(self.violation_frac - self.completed_frac, 0.0) \
                * self.task.runtime_h * self.slowdown
        return self.ttf * self.task.runtime_h * self.slowdown

    # ------------------------------------------------------------- records
    def record_failure(self) -> bool:
        """Account one killed attempt; returns True when the task must be
        aborted (capacity exhausted or the safety valve tripped).

        Boundary: ``attempts`` counts *dispatched* attempts and starts at 1;
        ``apply_retry`` increments it only when a further attempt is
        actually granted. The valve therefore trips on the failure of the
        MAX_ATTEMPTS-th attempt — exactly MAX_ATTEMPTS attempts run, never
        MAX_ATTEMPTS + 1 (pinned in tests/test_cluster_hetero.py).
        """
        if self.plan is not None:
            # temporal OOM: everything reserved up to the violation burned
            # (from the resume point for a retained plan; cf == 0.0 keeps
            # the default path bitwise)
            frac = self.violation_frac
            burn = self._reserved_gbh(frac, self.completed_frac)
            self.wastage_gbh += burn
            self.tw_gbh += burn
            self.runtime_h += max(frac - self.completed_frac, 0.0) \
                * self.task.runtime_h * self.slowdown
        else:
            burn = self.alloc_gb * self.ttf * self.task.runtime_h \
                * self.slowdown
            self.wastage_gbh += burn
            self.tw_gbh += burn
            self.runtime_h += self.ttf * self.task.runtime_h * self.slowdown
        self.oom_gbh += burn
        # an OOM kill loses the process: checkpoints of the too-small
        # attempt are not resumable by the larger re-run (strict-limit
        # semantics — the working set never fit), so retention resets
        self.completed_frac = 0.0
        self.failures += 1
        if self.alloc_gb >= self.cap_gb or self.attempts >= MAX_ATTEMPTS:
            self.aborted = True
        return self.aborted

    def record_interruption(self, elapsed_h: float, *,
                            charge_interruption: bool = True) -> None:
        """A preemption or node crash killed the attempt ``elapsed_h`` into
        its run. This is NOT an OOM failure: no failure count, no
        retry-ladder step, no abort pressure.

        ``charge_interruption=False`` keeps the burn out of
        ``interruption_gbh``: temporal grow *denials* use the same
        burn-and-requeue arithmetic but are placement congestion, not a
        failure event — they must not pollute the Ponder-style
        failure-waste axis of a crash-free run.

        Under ``retry_same`` / ``retry_scaled`` the whole partial
        reservation is burned (nothing useful survives the kill) and the
        attempt re-runs in full. Under ``checkpoint`` an attempt that
        would have succeeded retains completed work: a flat attempt the
        prefix up to its last ``checkpoint_frac`` checkpoint, a temporal
        attempt the prefix up to the last *plan segment boundary* it
        passed (segment boundaries are the plan's natural checkpoints —
        the reservation changes there anyway). Only the since-checkpoint
        reservation is truly lost (``interruption_gbh``); the retained
        prefix is charged its over-provisioning headroom, and
        ``completed_frac`` advances so the re-run executes only the
        suffix. A retained temporal attempt KEEPS its plan and resumes
        the reservation schedule mid-plan (``start_alloc_gb`` /
        ``_reserved_gbh`` read from ``completed_frac``). Doomed attempts
        never retain (see module docstring)."""
        retained = self.completed_frac
        if (self.failure_strategy == "checkpoint"
                and self.checkpoint_frac > 0 and self.will_succeed):
            wall_rt = self.task.runtime_h * self.slowdown
            pos = self.completed_frac + elapsed_h / max(wall_rt, 1e-12)
            if self.plan is None:
                retained = min(math.floor(pos / self.checkpoint_frac)
                               * self.checkpoint_frac, 1.0)
                retained = max(retained, self.completed_frac)
            else:
                # temporal: the last plan boundary reached (1.0 is the plan
                # end, not a resumable boundary)
                for end, _gb in self.plan.segments[:-1]:
                    if self.completed_frac < end <= pos + 1e-12:
                        retained = end
        if retained > self.completed_frac:
            wall_rt = self.task.runtime_h * self.slowdown
            retained_dt = (retained - self.completed_frac) * wall_rt
            # the retained prefix DID useful work: charge only headroom
            # (peak-based for wastage_gbh, curve-integrated for tw_gbh —
            # the same split record_success uses)
            used_gbh = (self.task.usage_gbh(retained)
                        - self.task.usage_gbh(self.completed_frac)) \
                * self.slowdown
            if self.plan is not None:
                # reservation followed the plan: the retained window is
                # charged plan-minus-used, the lost [retained, pos] window
                # burned in full (a temporal attempt's wastage IS its
                # integral — same convention as record_success)
                pos = min(self.completed_frac
                          + elapsed_h / max(wall_rt, 1e-12), 1.0)
                res_retained = self._reserved_gbh(retained,
                                                  self.completed_frac)
                lost = self._reserved_gbh(pos, retained)
                self.wastage_gbh += lost + (res_retained - used_gbh)
                self.tw_gbh += lost + (res_retained - used_gbh)
            else:
                lost_dt = max(elapsed_h - retained_dt, 0.0)
                lost = self.alloc_gb * lost_dt
                self.wastage_gbh += lost + (self.alloc_gb
                                            - self.task.actual_peak_gb) \
                    * retained_dt
                self.tw_gbh += lost + (self.alloc_gb * retained_dt
                                       - used_gbh)
            if charge_interruption:
                self.interruption_gbh += lost
            self.completed_frac = retained
        else:
            if self.plan is not None:
                frac = min(elapsed_h / max(self.task.runtime_h
                                           * self.slowdown, 1e-12), 1.0)
                burn = self._reserved_gbh(frac)
            else:
                burn = self.alloc_gb * elapsed_h
            self.wastage_gbh += burn
            self.tw_gbh += burn
            if charge_interruption:
                self.interruption_gbh += burn
        self.runtime_h += elapsed_h
        self.interruptions += 1

    def record_grow_failure(self, elapsed_h: float) -> None:
        """A segment-boundary grow found its node too full: interruption
        accounting (the partial plan integral is burned, no OOM), plus a
        grow-failure count — but NOT charged to ``interruption_gbh``: a
        denied grow is placement congestion, not a failure event, so the
        failure-waste axis of a crash-free run stays zero. After
        ``MAX_GROW_FAILURES`` denied grows the plan flattens to a constant
        ``alloc_gb`` (== the plan peak) reservation — placement then
        treats the task like any peak attempt and serializes it, so two
        growers can never requeue-livelock each other on a saturated
        node."""
        self.record_interruption(elapsed_h, charge_interruption=False)
        self.grow_failures += 1
        if self.grow_failures >= MAX_GROW_FAILURES:
            self.plan = None
            self._violation = False

    def apply_retry(self, method) -> float:
        """Ask the method for the next allocation (clamped to capacity).
        Retries are always FLAT: after an OOM the ladder sizes
        conservatively, so any plan of the failed attempt is dropped."""
        self.alloc_gb = min(
            float(method.retry(self.task, self.failures, self.alloc_gb)),
            self.cap_gb)
        self.attempts += 1
        self.plan = None
        self._violation = False
        return self.alloc_gb

    def apply_retry_alloc(self, alloc_gb: float) -> float:
        """Journal-replay variant of :meth:`apply_retry`: apply a
        previously *recorded* retry allocation without consulting the
        method (whose mutable pool state has moved on since the decision
        was journaled). Same ladder semantics: clamp, count the attempt,
        drop any plan."""
        self.alloc_gb = min(float(alloc_gb), self.cap_gb)
        self.attempts += 1
        self.plan = None
        self._violation = False
        return self.alloc_gb

    # -------------------------------------------------------- durability
    _STATE_FIELDS = ("first_alloc_gb", "cap_gb", "ttf", "alloc_gb",
                     "attempts", "failures", "wastage_gbh", "runtime_h",
                     "aborted", "interruptions", "tw_gbh", "grow_failures",
                     "failure_strategy", "checkpoint_frac", "completed_frac",
                     "slowdown", "oom_gbh", "interruption_gbh",
                     "refresh_pending")

    def to_state(self) -> dict:
        """JSON-safe snapshot of the ledger (task carried by key — the
        trace is the caller's to re-resolve). Floats round-trip exactly
        through ``json`` (shortest-repr), so a restored ledger is bitwise
        the live one."""
        state = {f: getattr(self, f) for f in self._STATE_FIELDS}
        state["task"] = list(self.task.key)
        state["plan"] = ([list(s) for s in self.plan.segments]
                        if self.plan is not None else None)
        return state

    @classmethod
    def from_state(cls, task: TaskInstance, state: dict) -> "AttemptLedger":
        led = cls(task, state["first_alloc_gb"], state["cap_gb"],
                  state["ttf"], failure_strategy=state["failure_strategy"],
                  checkpoint_frac=state["checkpoint_frac"])
        for f in cls._STATE_FIELDS:
            setattr(led, f, state[f])
        if state["plan"] is not None:
            led.plan = ReservationPlan(
                tuple((float(e), float(g)) for e, g in state["plan"]))
        # _violation stays un-computed: the cache is re-derived on demand
        # from (plan, curve), both of which round-trip exactly
        return led

    def record_success(self) -> None:
        # wall time of the successful run: straggler-stretched, shrunk to
        # the un-retained suffix under checkpoint retention (both factors
        # are exactly 1.0 on the default path — bitwise-inert)
        rt = self.task.runtime_h * self.slowdown * (1.0 - self.completed_frac)
        if self.completed_frac > 0.0:
            used = (self.task.usage_gbh()
                    - self.task.usage_gbh(self.completed_frac)) \
                * self.slowdown
        else:
            used = self.task.usage_gbh() * self.slowdown
        if self.plan is not None:
            tw = self._reserved_gbh(1.0, self.completed_frac) - used
            # a temporal attempt's "peak-based" wastage IS its integral —
            # there is no meaningful constant-reservation reading of a plan
            self.wastage_gbh += tw
            self.tw_gbh += tw
        else:
            self.wastage_gbh += (self.alloc_gb - self.task.actual_peak_gb) \
                * rt
            self.tw_gbh += self.alloc_gb * rt - used
        self.runtime_h += rt

    def outcome(self, *, submit_h: float = 0.0, start_h: float = 0.0,
                finish_h: float = 0.0) -> TaskOutcome:
        return TaskOutcome(self.task, self.first_alloc_gb, self.alloc_gb,
                           self.attempts, self.failures, self.wastage_gbh,
                           self.runtime_h, self.aborted,
                           interruptions=self.interruptions,
                           tw_gbh=self.tw_gbh,
                           grow_failures=self.grow_failures,
                           oom_gbh=self.oom_gbh,
                           interruption_gbh=self.interruption_gbh,
                           submit_h=submit_h, start_h=start_h,
                           finish_h=finish_h)
