"""Per-attempt waste/retry arithmetic shared by the serial replay and the
event-driven cluster engine (paper §III-A semantics, one source of truth).

The serial simulator runs a task to completion in one tight loop; the
cluster engine interleaves attempts of many tasks across an event queue.
Both step the same ``AttemptLedger`` state machine, so the two paths
cannot drift apart:

  * a killed attempt burns its whole allocation for ``ttf * runtime``;
  * a successful attempt wastes ``(allocation - actual) * runtime`` GBh;
  * retries follow the method's own policy, clamped to the machine/node
    capacity; a task is aborted once even the capacity fails or the
    ``MAX_ATTEMPTS`` safety valve trips;
  * a *preempted* or *crash-killed* attempt (heterogeneous cluster engine)
    burns only the partial reservation it held — it is an interruption,
    not an OOM failure: no failure count, no retry-ladder step, no abort
    pressure.

``cap_gb`` is per-ledger: the serial replay passes the machine capacity
(or the task's own ``machine_cap_gb`` when the trace is heterogeneous),
the cluster engine the capacity of the *largest node the task could ever
be placed on* — so clamp/abort semantics follow the hardware the task can
actually reach, not a global constant.
"""
from __future__ import annotations

import dataclasses

from repro.workflow.trace import TaskInstance

MAX_ATTEMPTS = 16  # safety valve; the doubling ladder reaches any cap first


def doubling_retry(last_alloc_gb: float, cap_gb: float) -> float:
    """The standard resource-manager failure ladder: double, clamp to cap."""
    return min(last_alloc_gb * 2.0, cap_gb)


@dataclasses.dataclass
class TaskOutcome:
    task: TaskInstance
    first_alloc_gb: float
    final_alloc_gb: float
    attempts: int
    failures: int
    wastage_gbh: float
    runtime_h: float            # wall time incl. failed attempts
    aborted: bool = False
    interruptions: int = 0      # preemptions / node-crash kills (not OOMs)
    # event timestamps (filled by the simulators; serial replay uses a
    # running clock, the cluster engine real event times)
    submit_h: float = 0.0       # became ready / was submitted
    start_h: float = 0.0        # first attempt dispatched
    finish_h: float = 0.0       # completed or aborted

    @property
    def queue_delay_h(self) -> float:
        return self.start_h - self.submit_h


@dataclasses.dataclass
class AttemptLedger:
    """Mutable per-task attempt state, stepped identically by both engines."""
    task: TaskInstance
    first_alloc_gb: float
    cap_gb: float               # machine (serial) or node (cluster) capacity
    ttf: float
    alloc_gb: float = dataclasses.field(init=False)
    attempts: int = 1
    failures: int = 0
    wastage_gbh: float = 0.0
    runtime_h: float = 0.0
    aborted: bool = False
    interruptions: int = 0

    def __post_init__(self):
        self.alloc_gb = self.first_alloc_gb

    @property
    def will_succeed(self) -> bool:
        """Strict limits (assumption A3): the attempt survives iff the
        allocation covers the ground-truth peak."""
        return self.alloc_gb >= self.task.actual_peak_gb

    @property
    def attempt_duration_h(self) -> float:
        """Wall time of the *next* attempt: full runtime on success, the
        ttf-scaled prefix when the attempt will be OOM-killed."""
        return (self.task.runtime_h if self.will_succeed
                else self.ttf * self.task.runtime_h)

    def record_failure(self) -> bool:
        """Account one killed attempt; returns True when the task must be
        aborted (capacity exhausted or the safety valve tripped).

        Boundary: ``attempts`` counts *dispatched* attempts and starts at 1;
        ``apply_retry`` increments it only when a further attempt is
        actually granted. The valve therefore trips on the failure of the
        MAX_ATTEMPTS-th attempt — exactly MAX_ATTEMPTS attempts run, never
        MAX_ATTEMPTS + 1 (pinned in tests/test_cluster_hetero.py).
        """
        self.wastage_gbh += self.alloc_gb * self.ttf * self.task.runtime_h
        self.runtime_h += self.ttf * self.task.runtime_h
        self.failures += 1
        if self.alloc_gb >= self.cap_gb or self.attempts >= MAX_ATTEMPTS:
            self.aborted = True
        return self.aborted

    def record_interruption(self, elapsed_h: float) -> None:
        """A preemption or node crash killed the attempt ``elapsed_h`` into
        its run. The partial reservation is burned (``alloc * elapsed`` GBh
        — nothing useful was produced) but this is NOT an OOM failure: no
        failure count, no retry-ladder step, no abort pressure. The attempt
        re-runs later at the same allocation."""
        self.wastage_gbh += self.alloc_gb * elapsed_h
        self.runtime_h += elapsed_h
        self.interruptions += 1

    def apply_retry(self, method) -> float:
        """Ask the method for the next allocation (clamped to capacity)."""
        self.alloc_gb = min(
            float(method.retry(self.task, self.failures, self.alloc_gb)),
            self.cap_gb)
        self.attempts += 1
        return self.alloc_gb

    def record_success(self) -> None:
        self.wastage_gbh += ((self.alloc_gb - self.task.actual_peak_gb)
                             * self.task.runtime_h)
        self.runtime_h += self.task.runtime_h

    def outcome(self, *, submit_h: float = 0.0, start_h: float = 0.0,
                finish_h: float = 0.0) -> TaskOutcome:
        return TaskOutcome(self.task, self.first_alloc_gb, self.alloc_gb,
                           self.attempts, self.failures, self.wastage_gbh,
                           self.runtime_h, self.aborted,
                           interruptions=self.interruptions,
                           submit_h=submit_h, start_h=start_h,
                           finish_h=finish_h)
