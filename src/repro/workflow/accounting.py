"""Per-attempt waste/retry arithmetic shared by the serial replay and the
event-driven cluster engine (paper §III-A semantics, one source of truth).

The serial simulator runs a task to completion in one tight loop; the
cluster engine interleaves attempts of many tasks across an event queue.
Both step the same ``AttemptLedger`` state machine, so the two paths
cannot drift apart:

  * a killed attempt burns its whole allocation for ``ttf * runtime``;
  * a successful attempt wastes ``(allocation - actual) * runtime`` GBh;
  * retries follow the method's own policy, clamped to the machine/node
    capacity; a task is aborted once even the capacity fails or the
    ``MAX_ATTEMPTS`` safety valve trips;
  * a *preempted* or *crash-killed* attempt (heterogeneous cluster engine)
    burns only the partial reservation it held — it is an interruption,
    not an OOM failure: no failure count, no retry-ladder step, no abort
    pressure.

Temporal attempts (KS+-style time-segmented allocators) extend the state
machine without touching the legacy arithmetic:

  * a :class:`~repro.core.temporal.segments.ReservationPlan` with >= 2
    segments makes the attempt *temporal*: the reservation follows the
    plan (the engines resize at segment boundaries) and success requires
    the plan to cover the task's ground-truth ``usage_curve`` at every
    time, not just its peak;
  * a temporal OOM kill happens at the curve's first crossing of the plan
    (the violation time IS the time-to-failure, so ``ttf`` does not scale
    it) and burns the plan's partial reservation integral;
  * a plan with ONE segment is a constant reservation — it is executed on
    the legacy peak path, arithmetic bitwise-identical to a plain
    allocation (the resize-disabled / k=1 configuration);
  * retries after any failure fall back to a FLAT reservation from the
    method's ladder (after an OOM you size conservatively), as do plans
    that failed to grow ``MAX_GROW_FAILURES`` times on a busy node.

Every ledger additionally tracks **time-integrated waste** ``tw_gbh``:
integral of (reserved(t) - used(t)) over the attempt, using the task's
usage curve (flat at the peak when the trace carries none — in which case
``tw_gbh == wastage_gbh`` exactly). Peak and temporal allocators therefore
plot on one Fig. 8-style GB·h axis.

``cap_gb`` is per-ledger: the serial replay passes the machine capacity
(or the task's own ``machine_cap_gb`` when the trace is heterogeneous),
the cluster engine the capacity of the *largest node the task could ever
be placed on* — so clamp/abort semantics follow the hardware the task can
actually reach, not a global constant.
"""
from __future__ import annotations

import dataclasses

from repro.core.temporal.segments import ReservationPlan
from repro.workflow.trace import TaskInstance

MAX_ATTEMPTS = 16  # safety valve; the doubling ladder reaches any cap first

# after this many failed reservation *grows* (node too full at a segment
# boundary) the plan flattens to a constant peak reservation — placement
# then serializes the task like any peak attempt, guaranteeing progress
MAX_GROW_FAILURES = 3


def doubling_retry(last_alloc_gb: float, cap_gb: float) -> float:
    """The standard resource-manager failure ladder: double, clamp to cap."""
    return min(last_alloc_gb * 2.0, cap_gb)


@dataclasses.dataclass
class TaskOutcome:
    task: TaskInstance
    first_alloc_gb: float
    final_alloc_gb: float
    attempts: int
    failures: int
    wastage_gbh: float
    runtime_h: float            # wall time incl. failed attempts
    aborted: bool = False
    interruptions: int = 0      # preemptions / node-crash kills (not OOMs)
    # time-integrated waste: integral of reserved-minus-used GB·h over the
    # task's attempts (== wastage_gbh when the trace carries no usage
    # curves). The one axis peak and temporal allocators share.
    tw_gbh: float = 0.0
    grow_failures: int = 0      # denied reservation grows (temporal plans)
    # event timestamps (filled by the simulators; serial replay uses a
    # running clock, the cluster engine real event times)
    submit_h: float = 0.0       # became ready / was submitted
    start_h: float = 0.0        # first attempt dispatched
    finish_h: float = 0.0       # completed or aborted

    @property
    def queue_delay_h(self) -> float:
        return self.start_h - self.submit_h


@dataclasses.dataclass
class AttemptLedger:
    """Mutable per-task attempt state, stepped identically by both engines."""
    task: TaskInstance
    first_alloc_gb: float
    cap_gb: float               # machine (serial) or node (cluster) capacity
    ttf: float
    alloc_gb: float = dataclasses.field(init=False)
    attempts: int = 1
    failures: int = 0
    wastage_gbh: float = 0.0
    runtime_h: float = 0.0
    aborted: bool = False
    interruptions: int = 0
    tw_gbh: float = 0.0
    # temporal state: the reservation plan of the CURRENT attempt (None =
    # flat legacy reservation at alloc_gb)
    plan: ReservationPlan | None = None
    grow_failures: int = 0

    def __post_init__(self):
        self.alloc_gb = self.first_alloc_gb
        self._violation: float | None | bool = False  # False = not computed

    # ------------------------------------------------------------ temporal
    def set_plan(self, plan: ReservationPlan | None) -> None:
        """Attach a reservation plan to the current attempt. Single-segment
        plans are a constant reservation == the legacy path; they are
        dropped here so every downstream branch sees ``temporal_active ==
        False`` and the arithmetic stays bitwise-identical to a plain
        allocation (the k=1 acceptance invariant)."""
        if plan is not None:
            plan = plan.simplify()
            if plan.k <= 1:
                plan = None
        self.plan = plan
        self._violation = False

    @property
    def temporal_active(self) -> bool:
        return self.plan is not None

    @property
    def start_alloc_gb(self) -> float:
        """What dispatch actually reserves: the plan's first segment for a
        temporal attempt, the flat allocation otherwise."""
        return self.plan.start_gb if self.plan is not None else self.alloc_gb

    @property
    def violation_frac(self) -> float | None:
        """First runtime fraction where usage exceeds the plan (None =
        the plan covers the whole curve). An empty ``usage_curve`` means
        "flat at the peak" (legacy trace semantics), so a plan must cover
        ``actual_peak_gb`` for the whole runtime there — a multi-segment
        plan can never dodge an OOM just because the trace carries no
        time-resolved ground truth. Cached per attempt."""
        if self._violation is False:
            if self.plan is None:
                self._violation = None
            else:
                curve = (self.task.usage_curve
                         or ((1.0, self.task.actual_peak_gb),))
                self._violation = self.plan.first_violation(curve)
        return self._violation

    def _reserved_gbh(self, upto_frac: float) -> float:
        """GB·h reserved over the first ``upto_frac`` of the runtime under
        the current attempt's reservation (plan or flat)."""
        if self.plan is not None:
            return self.plan.gbh(self.task.runtime_h, upto_frac)
        return self.alloc_gb * upto_frac * self.task.runtime_h

    # ------------------------------------------------------------- queries
    @property
    def will_succeed(self) -> bool:
        """Strict limits (assumption A3): the attempt survives iff the
        reservation covers the ground-truth usage — the peak for a flat
        attempt, the whole curve for a temporal one."""
        if self.plan is not None:
            return self.violation_frac is None
        return self.alloc_gb >= self.task.actual_peak_gb

    @property
    def attempt_duration_h(self) -> float:
        """Wall time of the *next* attempt: full runtime on success. A
        flat attempt that will OOM runs for the ttf-scaled prefix (the
        paper's simulation parameter); a temporal attempt dies exactly at
        the curve's first crossing of the plan (the violation time IS the
        time-to-failure, so ttf does not apply)."""
        if self.will_succeed:
            return self.task.runtime_h
        if self.plan is not None:
            return self.violation_frac * self.task.runtime_h
        return self.ttf * self.task.runtime_h

    # ------------------------------------------------------------- records
    def record_failure(self) -> bool:
        """Account one killed attempt; returns True when the task must be
        aborted (capacity exhausted or the safety valve tripped).

        Boundary: ``attempts`` counts *dispatched* attempts and starts at 1;
        ``apply_retry`` increments it only when a further attempt is
        actually granted. The valve therefore trips on the failure of the
        MAX_ATTEMPTS-th attempt — exactly MAX_ATTEMPTS attempts run, never
        MAX_ATTEMPTS + 1 (pinned in tests/test_cluster_hetero.py).
        """
        if self.plan is not None:
            # temporal OOM: everything reserved up to the violation burned
            frac = self.violation_frac
            burn = self._reserved_gbh(frac)
            self.wastage_gbh += burn
            self.tw_gbh += burn
            self.runtime_h += frac * self.task.runtime_h
        else:
            burn = self.alloc_gb * self.ttf * self.task.runtime_h
            self.wastage_gbh += burn
            self.tw_gbh += burn
            self.runtime_h += self.ttf * self.task.runtime_h
        self.failures += 1
        if self.alloc_gb >= self.cap_gb or self.attempts >= MAX_ATTEMPTS:
            self.aborted = True
        return self.aborted

    def record_interruption(self, elapsed_h: float) -> None:
        """A preemption or node crash killed the attempt ``elapsed_h`` into
        its run. The partial reservation is burned (its time integral —
        nothing useful was produced) but this is NOT an OOM failure: no
        failure count, no retry-ladder step, no abort pressure. The attempt
        re-runs later under the same reservation (plan included)."""
        if self.plan is not None:
            frac = min(elapsed_h / max(self.task.runtime_h, 1e-12), 1.0)
            burn = self._reserved_gbh(frac)
        else:
            burn = self.alloc_gb * elapsed_h
        self.wastage_gbh += burn
        self.tw_gbh += burn
        self.runtime_h += elapsed_h
        self.interruptions += 1

    def record_grow_failure(self, elapsed_h: float) -> None:
        """A segment-boundary grow found its node too full: interruption
        accounting (the partial plan integral is burned, no OOM), plus a
        grow-failure count. After ``MAX_GROW_FAILURES`` denied grows the
        plan flattens to a constant ``alloc_gb`` (== the plan peak)
        reservation — placement then treats the task like any peak attempt
        and serializes it, so two growers can never requeue-livelock each
        other on a saturated node."""
        self.record_interruption(elapsed_h)
        self.grow_failures += 1
        if self.grow_failures >= MAX_GROW_FAILURES:
            self.plan = None
            self._violation = False

    def apply_retry(self, method) -> float:
        """Ask the method for the next allocation (clamped to capacity).
        Retries are always FLAT: after an OOM the ladder sizes
        conservatively, so any plan of the failed attempt is dropped."""
        self.alloc_gb = min(
            float(method.retry(self.task, self.failures, self.alloc_gb)),
            self.cap_gb)
        self.attempts += 1
        self.plan = None
        self._violation = False
        return self.alloc_gb

    def record_success(self) -> None:
        rt = self.task.runtime_h
        used = self.task.usage_gbh()   # == peak * rt for curve-less traces
        if self.plan is not None:
            tw = self._reserved_gbh(1.0) - used
            # a temporal attempt's "peak-based" wastage IS its integral —
            # there is no meaningful constant-reservation reading of a plan
            self.wastage_gbh += tw
            self.tw_gbh += tw
        else:
            self.wastage_gbh += (self.alloc_gb - self.task.actual_peak_gb) \
                * rt
            self.tw_gbh += self.alloc_gb * rt - used
        self.runtime_h += rt

    def outcome(self, *, submit_h: float = 0.0, start_h: float = 0.0,
                finish_h: float = 0.0) -> TaskOutcome:
        return TaskOutcome(self.task, self.first_alloc_gb, self.alloc_gb,
                           self.attempts, self.failures, self.wastage_gbh,
                           self.runtime_h, self.aborted,
                           interruptions=self.interruptions,
                           tw_gbh=self.tw_gbh,
                           grow_failures=self.grow_failures,
                           submit_h=submit_h, start_h=start_h,
                           finish_h=finish_h)
