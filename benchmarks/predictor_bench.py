"""Throughput microbenchmark for the Sizey decision loop.

Measures predictions/sec and observes/sec of the fused single-dispatch
predictor against the pre-fusion per-model-loop reference, at history
sizes 10/100/1000, single-task and batched (the batched scheduler API).

    PYTHONPATH=src python -m benchmarks.predictor_bench [--scale 1.0]
                          [--out BENCH_predictor.json]

``--scale`` shrinks repetition counts (and drops the 1000-row history below
0.25) so ``--scale 0.05`` is a seconds-long smoke run that still exercises
the fused path end-to-end; scale 1.0 produces the numbers quoted in
CHANGES.md. Writes a JSON report with per-size throughput and the
fused-over-loop speedup ratios.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import SizeyConfig
from repro.core.predictor import SizeyPredictor, TaskQuery

HISTORY_SIZES = (10, 100, 1000)
BATCH = 64


def _make_predictor(n_history: int, *, fused: bool,
                    incremental: bool) -> SizeyPredictor:
    cfg = SizeyConfig(incremental=incremental, mlp_train_steps=50)
    p = SizeyPredictor(cfg, fused=fused)
    rng = np.random.default_rng(0)
    xs = rng.uniform(0.5, 8.0, n_history)
    ys = 2.0 * xs + rng.normal(0.0, 0.2, n_history)
    for x, y in zip(xs, ys):
        d = p.predict("bench", "m", (float(x),), 32.0)
        p.observe(d, float(max(y, 0.1)), 0.5)
    return p


def _time_per_call(fn, reps: int) -> float:
    fn()  # warmup (compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(scale: float = 1.0, out_path: str = "BENCH_predictor.json") -> dict:
    sizes = [n for n in HISTORY_SIZES if scale >= 0.25 or n <= 100]
    reps = max(int(200 * scale), 3)
    obs_reps = max(int(50 * scale), 3)
    report: dict = {"scale": scale, "batch": BATCH, "history": {}}

    for n in sizes:
        row: dict = {}
        for label, fused in (("loop", False), ("fused", True)):
            p = _make_predictor(n, fused=fused, incremental=True)
            t = _time_per_call(
                lambda: p.predict("bench", "m", (3.0,), 32.0), reps)
            row[f"predict_{label}_per_s"] = 1.0 / t

            queries = [TaskQuery("bench", "m", (float(v),), 32.0)
                       for v in np.linspace(0.5, 8.0, BATCH)]
            t = _time_per_call(lambda: p.predict_batch(queries), reps)
            row[f"predict_batch_{label}_per_s"] = BATCH / t

            def one_observe(p=p):
                d = p.predict("bench", "m", (3.0,), 32.0)
                p.observe(d, 6.0, 0.5)
                # rewind the appended history + log row (count AND mask) so
                # every timed iteration sees the identical n-row pool
                pool = p.db.pool("bench", "m")
                pool.count = n
                pool.mask = pool.mask.at[n].set(0.0)
                pool.log_count -= 1
                pool.log_mask = pool.log_mask.at[pool.log_count].set(0.0)

            t = _time_per_call(one_observe, obs_reps)
            row[f"observe_{label}_per_s"] = 1.0 / t

        row["predict_speedup"] = (row["predict_fused_per_s"]
                                  / row["predict_loop_per_s"])
        row["predict_batch_speedup"] = (row["predict_batch_fused_per_s"]
                                        / row["predict_batch_loop_per_s"])
        row["observe_speedup"] = (row["observe_fused_per_s"]
                                  / row["observe_loop_per_s"])
        report["history"][n] = row
        print(f"history={n:5d} "
              f"predict {row['predict_loop_per_s']:8.0f}/s -> "
              f"{row['predict_fused_per_s']:8.0f}/s "
              f"({row['predict_speedup']:.1f}x)  "
              f"batch {row['predict_batch_loop_per_s']:8.0f}/s -> "
              f"{row['predict_batch_fused_per_s']:8.0f}/s "
              f"({row['predict_batch_speedup']:.1f}x)  "
              f"observe {row['observe_loop_per_s']:7.0f}/s -> "
              f"{row['observe_fused_per_s']:7.0f}/s "
              f"({row['observe_speedup']:.1f}x)", flush=True)

    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="0.05 = smoke mode (seconds); 1.0 = full numbers")
    ap.add_argument("--out", default="BENCH_predictor.json")
    args = ap.parse_args()
    run(scale=args.scale, out_path=args.out)


if __name__ == "__main__":
    main()
