"""Benchmark harness: one function per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.35] [--ttf 1.0 0.5]

Prints ``name,metric=value,...`` CSV lines (and human-readable tables) and
writes results/bench_results.json for EXPERIMENTS.md. Scale 1.0 replays
the paper's full Table I instance counts; the default 0.35 keeps the whole
suite ~10 minutes on CPU while preserving every qualitative result.

  fig8a  wastage over time, ttf=1.0, aggregated over the six workflows
  fig8b  wastage over time, ttf=0.5
  fig8c  task-failure distribution by task type
  fig8d  aggregated task runtimes
  table2 per-workflow wastage for all methods
  fig9   full vs incremental (re)training time
  fig10  alpha sweep on two rnaseq task types
  fig11  model-class selection shares (argmax)
  fig12  relative prediction-error trend over task executions
  roofline  three-term roofline per (arch x shape x mesh) from the dry-run

``--smoke`` additionally runs the predictor and cluster-engine
microbenchmarks (benchmarks/predictor_bench.py, benchmarks/cluster_bench.py)
at the same scale.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks._util import dump_json
from benchmarks.roofline import csv_rows, load_rows
from repro.baselines import make_method
from repro.baselines.sizey_method import SizeyMethod
from repro.core import SizeyConfig
from repro.workflow import WORKFLOWS, generate_workflow, simulate

METHODS = ("sizey", "witt_wastage", "witt_lr", "tovar_ppm",
           "witt_percentile", "workflow_presets")


def _method(name: str, ttf: float):
    if name == "sizey":
        return SizeyMethod(SizeyConfig(), ttf=ttf)
    if name == "sizey_incremental":
        return SizeyMethod(SizeyConfig(incremental=True), ttf=ttf,
                           name="sizey_incremental")
    if name == "sizey_argmax":
        return SizeyMethod(SizeyConfig(strategy="argmax"), ttf=ttf,
                           name="sizey_argmax")
    return make_method(name, ttf=ttf)


class SimGrid:
    """Runs (workflow x method x ttf) once; figures share the results."""

    def __init__(self, scale: float, ttfs: tuple[float, ...]):
        self.scale = scale
        self.ttfs = ttfs
        self.results: dict[tuple, object] = {}
        self.methods_store: dict[tuple, object] = {}

    def run(self):
        for wf in WORKFLOWS:
            trace = generate_workflow(wf, scale=self.scale)
            for ttf in self.ttfs:
                for m in METHODS:
                    t0 = time.time()
                    method = _method(m, ttf)
                    r = simulate(trace, method, ttf=ttf)
                    self.results[(wf, m, ttf)] = r
                    self.methods_store[(wf, m, ttf)] = method
                    print(f"# sim {wf:10s} {m:18s} ttf={ttf} "
                          f"wastage={r.wastage_gbh:10.2f} "
                          f"fail={r.n_failures:4d} "
                          f"({time.time()-t0:.1f}s)", flush=True)
        return self

    def agg_wastage(self, method: str, ttf: float) -> float:
        return sum(self.results[(wf, method, ttf)].wastage_gbh
                   for wf in WORKFLOWS)

    def agg_runtime(self, method: str, ttf: float) -> float:
        return sum(self.results[(wf, method, ttf)].total_runtime_h
                   for wf in WORKFLOWS)

    def failures_by_type(self, method: str, ttf: float) -> list[int]:
        out = []
        for wf in WORKFLOWS:
            out.extend(self.results[(wf, method, ttf)]
                       .failures_by_type().values())
        return out


# ------------------------------------------------------------- figures
def bench_fig8ab(grid: SimGrid, ttf: float, out: dict):
    name = "fig8a" if ttf == 1.0 else "fig8b"
    rows = {m: grid.agg_wastage(m, ttf) for m in METHODS}
    best_baseline = min(v for k, v in rows.items() if k != "sizey")
    red = 100 * (1 - rows["sizey"] / best_baseline)
    out[name] = {"wastage_gbh": rows, "sizey_vs_best_baseline_pct": red}
    for m, v in rows.items():
        print(f"{name}/{m},wastage_gbh={v:.2f}")
    print(f"{name}/sizey_reduction,pct={red:.2f} "
          f"(paper: {64.58 if ttf == 1.0 else 60.60})")


def bench_fig8c(grid: SimGrid, out: dict):
    res = {}
    for m in METHODS:
        fails = grid.failures_by_type(m, 1.0)
        res[m] = {"median": float(np.median(fails)),
                  "q3": float(np.percentile(fails, 75)),
                  "total": int(np.sum(fails))}
        print(f"fig8c/{m},median_failures_per_type={res[m]['median']:.1f},"
              f"total={res[m]['total']}")
    out["fig8c"] = res


def bench_fig8d(grid: SimGrid, out: dict):
    res = {m: grid.agg_runtime(m, 1.0) for m in METHODS}
    out["fig8d"] = res
    for m, v in res.items():
        print(f"fig8d/{m},runtime_h={v:.2f}")


def bench_table2(grid: SimGrid, out: dict):
    table = {}
    for wf in WORKFLOWS:
        table[wf] = {m: grid.results[(wf, m, 1.0)].wastage_gbh
                     for m in METHODS}
        best_baseline = min(v for k, v in table[wf].items() if k != "sizey")
        win = table[wf]["sizey"] < best_baseline
        print(f"table2/{wf}," + ",".join(
            f"{m}={v:.2f}" for m, v in table[wf].items())
            + f",sizey_best={win}")
    wins = sum(table[wf]["sizey"] < min(v for k, v in table[wf].items()
                                        if k != "sizey")
               for wf in WORKFLOWS)
    print(f"table2/summary,sizey_best_in={wins}_of_{len(WORKFLOWS)} "
          f"(paper: 5 of 6)")
    out["table2"] = table
    out["table2_wins"] = wins


def bench_fig9(scale: float, out: dict):
    trace = generate_workflow("methylseq", scale=scale)
    full = _method("sizey", 1.0)
    inc = _method("sizey_incremental", 1.0)
    simulate(trace, full, ttf=1.0)
    simulate(trace, inc, ttf=1.0)
    t_full = float(np.median(full.predictor.train_times_s)) * 1e3
    t_inc = float(np.median(inc.predictor.train_times_s)) * 1e3
    red = 100 * (1 - t_inc / t_full)
    out["fig9"] = {"full_ms": t_full, "incremental_ms": t_inc,
                   "reduction_pct": red}
    print(f"fig9/full,median_train_ms={t_full:.2f}")
    print(f"fig9/incremental,median_train_ms={t_inc:.2f}")
    print(f"fig9/reduction,pct={red:.1f} (paper: 98.39, 1090ms -> 17.5ms)")


def bench_fig10(scale: float, out: dict):
    trace = generate_workflow("rnaseq", scale=scale)
    tasks = ("fastqc", "markduplicates")
    res: dict[str, dict] = {t: {} for t in tasks}
    for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
        method = SizeyMethod(SizeyConfig(alpha=alpha), ttf=1.0)
        r = simulate(trace, method, ttf=1.0)
        per_type: dict[str, float] = {}
        for o in r.outcomes:
            per_type[o.task.task_type] = per_type.get(o.task.task_type, 0) \
                + o.wastage_gbh
        for t in tasks:
            res[t][str(alpha)] = per_type.get(t, 0.0)
        print(f"fig10/alpha={alpha}," + ",".join(
            f"{t}={per_type.get(t, 0):.2f}" for t in tasks))
    out["fig10"] = res


def bench_fig11(grid: SimGrid, out: dict):
    # argmax run across all workflows: which model class wins (Fig. 11)
    counts = np.zeros(4)
    names = None
    for wf in WORKFLOWS:
        trace = generate_workflow(wf, scale=grid.scale)
        method = _method("sizey_argmax", 1.0)
        simulate(trace, method, ttf=1.0)
        counts = counts + method.predictor.model_select_counts
        names = method.predictor.models
    shares = counts / max(counts.sum(), 1)
    out["fig11"] = dict(zip(names, map(float, shares)))
    print("fig11/shares," + ",".join(
        f"{n}={s*100:.1f}%" for n, s in zip(names, shares))
        + "  (paper: mlp=42.7%, knn=29.1%, forest=19.4%, linear=8.8%)")


def bench_fig12(scale: float, out: dict):
    trace = generate_workflow("mag", scale=scale)
    method = _method("sizey", 1.0)
    simulate(trace, method, ttf=1.0)
    # raw aggregate predictions (no offset) from the prequential log
    pool = method.predictor.db.pool("prokka", "epyc128")
    n = pool.log_count
    err = np.abs(pool.log_agg[:n] - pool.log_actual[:n]) \
        / np.maximum(pool.log_actual[:n], 1e-9)
    half = n // 2
    early, late = float(np.median(err[:half])), float(np.median(err[half:]))
    slope = float(np.polyfit(np.arange(n), err, 1)[0])
    out["fig12"] = {"n": int(n), "early_median_rel_err": early,
                    "late_median_rel_err": late, "slope_per_task": slope}
    print(f"fig12/prokka,n={n},early_err={early:.4f},late_err={late:.4f},"
          f"slope={slope:.2e} (paper: decreasing trend)")


def bench_roofline(out: dict):
    rows = load_rows()
    if not rows:
        print("roofline,missing=results/dryrun.jsonl")
        return
    for line in csv_rows(rows):
        print(line)
    ok = [r for r in rows if "skipped" not in r]
    out["roofline_cells"] = len(ok)
    out["roofline_skipped"] = len(rows) - len(ok)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float,
                    default=float(os.environ.get("REPRO_BENCH_SCALE", 0.35)))
    ap.add_argument("--ttf", type=float, nargs="+", default=[1.0, 0.5])
    ap.add_argument("--skip-sims", action="store_true",
                    help="only the roofline table")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke mode: --scale 0.05, ttf=1.0 only, plus the "
                         "predictor microbenchmark at the same scale — a "
                         "minutes-long end-to-end pass over every bench "
                         "path for the fast test loop")
    ap.add_argument("--out", default="results/bench_results.json",
                    help="output JSON path (CI writes into results/fresh/ "
                         "so the committed baseline stays intact for the "
                         "check_regression gate)")
    args = ap.parse_args()
    if args.smoke:
        args.scale = 0.05
        args.ttf = [1.0]

    out: dict = {"scale": args.scale}
    t0 = time.time()
    if not args.skip_sims:
        grid = SimGrid(args.scale, tuple(args.ttf)).run()
        bench_fig8ab(grid, 1.0, out)
        if 0.5 in args.ttf:
            bench_fig8ab(grid, 0.5, out)
        bench_fig8c(grid, out)
        bench_fig8d(grid, out)
        bench_table2(grid, out)
        bench_fig9(args.scale, out)
        bench_fig10(args.scale, out)
        bench_fig11(grid, out)
        bench_fig12(max(args.scale, 0.3), out)
    if args.smoke:
        from benchmarks.predictor_bench import run as predictor_bench_run
        out["predictor_bench"] = predictor_bench_run(scale=args.scale,
                                                     out_path="")
        from benchmarks.cluster_bench import run as cluster_bench_run
        out["cluster_bench"] = cluster_bench_run(scale=args.scale,
                                                 out_path="")
    bench_roofline(out)

    dump_json(args.out, out)
    print(f"# total bench wall: {time.time()-t0:.0f}s; "
          f"wrote {args.out}")


if __name__ == "__main__":
    main()
