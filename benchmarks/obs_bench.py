"""Observability-plane benchmark: telemetry-off vs telemetry-on wall on
the engine smoke grid, plus deterministic span / quality-sample counts.

    PYTHONPATH=src python -m benchmarks.obs_bench \
        --out results/fresh/BENCH_obs.json \
        --trace-out results/fresh/obs_trace.json

Two claims are checked, mirroring the PR 9 contract:

  * **Disabled cost ~zero.** The off-mode engine cells run with no
    collector installed — every ``span()`` is one module-global ``None``
    check — and their deterministic work counters (``n_events``,
    ``n_scan_entries``, ``n_heap_pushes``) are gated at zero growth by
    ``check_regression.py``. Wall ratios are artifacts only (CI runners
    are noisy).
  * **Telemetry is side-effect-free.** Each traced run (spans on; the
    sizey cell also emits quality rows) must reproduce the untraced
    SimResult bitwise (``headline.traced_equals_untraced``), and the
    span / quality-sample counts are pure functions of (trace, config,
    seed) — gated at zero growth, so an instrumentation site silently
    moving onto a per-event path fails the build.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks._util import dump_json

from repro import obs
from repro.baselines import make_method
from repro.baselines.sizey_method import SizeyMethod
from repro.core.predictor import DISPATCH_COUNTS
from repro.obs.quality import read_quality_rows
from repro.workflow import generate_workflow, simulate_cluster

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "tests"))
from chaos import assert_results_equal  # noqa: E402

# engine smoke cells (trace scale, node count) — the ends of the
# engine_bench grid: small/cheap and the 6k-task / 256-node cell
SMOKE_GRID = ((0.2, 32), (1.0, 256))


def _replay(trace, n_nodes: int):
    method = make_method("workflow_presets",
                         machine_cap_gb=trace.machine_cap_gb)
    t0 = time.perf_counter()
    res = simulate_cluster(trace, method, n_nodes=n_nodes,
                           node_cap_gb=32.0)
    return time.perf_counter() - t0, res


def _span_summary(col) -> dict:
    return {"n_spans": col.total_spans(),
            "span_counts": dict(sorted(col.span_counts.items()))}


def run(out_path: str = "BENCH_obs.json",
        trace_out: str | None = None) -> dict:
    report: dict = {"engine_overhead": []}
    all_bitwise = True

    for scale, n_nodes in SMOKE_GRID:
        trace = generate_workflow("mag", seed=1, scale=scale,
                                  arrival_rate_per_h=2000.0)
        wall_off, res_off = _replay(trace, n_nodes)
        with obs.tracing() as col:
            wall_on, res_on = _replay(trace, n_nodes)
        assert_results_equal(res_off, res_on)
        slabel = f"{scale:g}".replace(".", "p")
        cell = {
            "label": f"mag_s{slabel}_n{n_nodes}",
            "n_tasks": len(trace.tasks), "n_nodes": n_nodes,
            "wall_off_s": round(wall_off, 3),
            "wall_on_s": round(wall_on, 3),
            "on_off_ratio": round(wall_on / wall_off, 3),
            # off-mode engine work counters: gated at zero growth
            "n_events": res_off.cluster.n_events,
            "n_scan_entries": res_off.cluster.n_scan_entries,
            "n_heap_pushes": res_off.cluster.n_heap_pushes,
            **_span_summary(col),
        }
        report["engine_overhead"].append(cell)
        print(f"obs_bench/{cell['label']},n_tasks={cell['n_tasks']},"
              f"wall_off={cell['wall_off_s']},wall_on={cell['wall_on_s']},"
              f"ratio={cell['on_off_ratio']},spans={cell['n_spans']}")

    # the sizey cell: full predictor loop traced WITH quality telemetry,
    # bitwise-checked against the untraced/untelemetered run
    trace = generate_workflow("mag", seed=1, scale=0.2)
    with obs.scoped_counters(DISPATCH_COUNTS) as dc:
        t0 = time.perf_counter()
        res_off = simulate_cluster(
            trace, SizeyMethod(machine_cap_gb=trace.machine_cap_gb),
            n_nodes=32)
        wall_off = time.perf_counter() - t0
        off_counters = {"predict_pool": dc["predict_pool"],
                        "observe_pool": dc["observe_pool"],
                        "decisions": dc["decisions"]}
    method = SizeyMethod(machine_cap_gb=trace.machine_cap_gb, quality=True)
    with obs.tracing() as col:
        t0 = time.perf_counter()
        res_on = simulate_cluster(trace, method, n_nodes=32)
        wall_on = time.perf_counter() - t0
    assert_results_equal(res_off, res_on)
    quality = read_quality_rows(method.predictor.db)
    assert len(quality) == len(trace.tasks), \
        f"{len(quality)} quality rows for {len(trace.tasks)} tasks"
    report["traced_sizey"] = {
        "n_tasks": len(trace.tasks),
        "wall_off_s": round(wall_off, 3), "wall_on_s": round(wall_on, 3),
        "on_off_ratio": round(wall_on / wall_off, 3),
        "off_counters": off_counters,
        "n_quality_samples": len(quality),
        "n_quality_pools": len({(q["task_type"], q["machine"])
                                for q in quality}),
        **_span_summary(col),
    }
    print(f"obs_bench/traced_sizey,wall_off={wall_off:.3f},"
          f"wall_on={wall_on:.3f},spans={col.total_spans()},"
          f"quality_samples={len(quality)}")

    report["headline"] = {
        "traced_equals_untraced": all_bitwise,
        "max_on_off_ratio": max(
            c["on_off_ratio"] for c in (*report["engine_overhead"],
                                        report["traced_sizey"])),
    }

    if trace_out:
        os.makedirs(os.path.dirname(trace_out) or ".", exist_ok=True)
        col.write_chrome_trace(trace_out)
        print(f"# wrote {trace_out} ({col.total_spans()} spans)")
    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="also export the sizey cell's spans as a "
                         "Chrome/Perfetto trace_event JSON artifact")
    args = ap.parse_args()
    run(out_path=args.out, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
