"""Resync EXPERIMENTS.md §1 full-scale numbers from the latest artifacts.

    PYTHONPATH=src python -m benchmarks.refresh_experiments
"""
from __future__ import annotations

import csv
import re

WFS = ("eager", "methylseq", "chipseq", "rnaseq", "mag", "iwd")
METHODS = ("sizey", "witt_wastage", "witt_lr", "tovar_ppm",
           "witt_percentile", "workflow_presets")


def main():
    rows = list(csv.DictReader(open("results/workflow_sim_full.csv")))
    t = {(r["workflow"], r["method"], float(r["ttf"])):
         float(r["wastage_gbh"]) for r in rows}

    lines = ["| method | " + " | ".join(WFS) + " | total |",
             "|---|" + "---|" * (len(WFS) + 1)]
    for m in METHODS:
        vals = [t[(w, m, 1.0)] for w in WFS]
        lines.append(f"| {m} | " + " | ".join(f"{v:.1f}" for v in vals)
                     + f" | {sum(vals):.1f} |")
    table = "\n".join(lines)

    wins = sum(t[(w, "sizey", 1.0)] < min(t[(w, m, 1.0)]
                                          for m in METHODS[1:]) for w in WFS)
    tot = {m: sum(t[(w, m, 1.0)] for w in WFS) for m in METHODS}
    tot05 = {m: sum(t[(w, m, 0.5)] for w in WFS) for m in METHODS}
    best = min(v for k, v in tot.items() if k != "sizey")
    best05 = min(v for k, v in tot05.items() if k != "sizey")
    red10 = 100 * (1 - tot["sizey"] / best)
    red05 = 100 * (1 - tot05["sizey"] / best05)
    ratio = tot["workflow_presets"] / tot["sizey"]
    others = [100 * (1 - tot["sizey"] / v) for k, v in tot.items()
              if k not in ("sizey", "witt_wastage")]

    summary = (f"\nFull scale (Table I instance counts, ~12.7k tasks/method):"
               f" Sizey is best in **{wins} of 6 workflows**; aggregate"
               f" reduction vs the best baseline **{red10:.1f}% at ttf=1.0**"
               f" and **{red05:.1f}% at ttf=0.5**; presets waste"
               f" {ratio:.1f}x Sizey. Raw data:"
               f" results/workflow_sim_full.csv.\n")

    s = open("EXPERIMENTS.md").read()
    s = re.sub(
        r"### Table II at full paper scale.*?### Variant ablations",
        f"### Table II at full paper scale (wastage GBh, ttf=1.0)\n\n"
        f"{table}\n{summary}\n### Variant ablations",
        s, flags=re.S)
    s = re.sub(
        r"\| best, −[\d.]+% \(full scale\) vs best baseline \|",
        f"| best, −{red10:.1f}% (full scale) vs best baseline |", s)
    s = re.sub(r"\*\*[\d.]+× Sizey\*\* \(full scale\)",
               f"**{ratio:.1f}× Sizey** (full scale)", s)
    s = re.sub(
        r"Against the remaining baselines Sizey's full-scale reduction is "
        r"[\d–\-0-9]+%",
        f"Against the remaining baselines Sizey's full-scale reduction is "
        f"{min(others):.0f}–{max(others):.0f}%", s)
    open("EXPERIMENTS.md", "w").write(s)
    print(table)
    print(summary)
    print(f"wins={wins}/6 red10={red10:.1f}% red05={red05:.1f}% "
          f"presets={ratio:.1f}x others={min(others):.0f}-{max(others):.0f}%")


if __name__ == "__main__":
    main()
