"""Baseline vs optimized roofline comparison (feeds EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import json
import os


def load(path):
    rows = {}
    if not os.path.exists(path):
        return rows
    for line in open(path):
        r = json.loads(line)
        if r.get("status") == "ok":
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def bound(r):
    rt = r["roofline"]
    return max(rt["compute_s"], rt["memory_s"], rt["collective_s"])


def main(base_path="results/dryrun.jsonl",
         opt_path="results/dryrun_optimized.jsonl"):
    base = load(base_path)
    opt = load(opt_path)
    print("| arch | shape | mesh | peak GB b->o | step-bound s b->o | "
          "speedup | bottleneck b->o |")
    print("|---|---|---|---|---|---|---|")
    speedups = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        sb, so = bound(b), bound(o)
        sp = sb / so if so > 0 else float("inf")
        speedups.append(sp)
        print(f"| {key[0]} | {key[1]} | {key[2]} | "
              f"{b['memory']['peak_gb']:.1f} -> {o['memory']['peak_gb']:.1f} | "
              f"{sb:.3e} -> {so:.3e} | {sp:.2f}x | "
              f"{b['roofline']['bottleneck']} -> "
              f"{o['roofline']['bottleneck']} |")
    if speedups:
        import statistics
        print(f"\nmedian step-bound speedup: "
              f"{statistics.median(speedups):.2f}x over {len(speedups)} cells")


if __name__ == "__main__":
    main()
