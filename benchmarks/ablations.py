"""Sizey variant ablations (EXPERIMENTS.md §1 extension).

    PYTHONPATH=src python -m benchmarks.ablations [--scale 0.3]

Varies one knob at a time against the paper-default configuration
(interpolation, alpha=0, full retrain, 4 model classes).
"""
from __future__ import annotations

import argparse

from repro.baselines.sizey_method import SizeyMethod
from repro.core import SizeyConfig
from repro.workflow import generate_workflow, simulate

VARIANTS = {
    "paper-default": SizeyConfig(),
    "argmax": SizeyConfig(strategy="argmax"),
    "adaptive-alpha": SizeyConfig(adaptive_alpha=True),
    "alpha=0.5": SizeyConfig(alpha=0.5),
    "alpha=1.0": SizeyConfig(alpha=1.0),
    "incremental": SizeyConfig(incremental=True),
    "no-mlp": SizeyConfig(model_classes=("linear", "knn", "forest")),
    "linear-only": SizeyConfig(model_classes=("linear",)),
}

WORKFLOWS = ("rnaseq", "mag", "eager")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3)
    args = ap.parse_args()

    print(f"| variant | {' | '.join(WORKFLOWS)} | total |")
    print("|---|" + "---|" * (len(WORKFLOWS) + 1))
    traces = {wf: generate_workflow(wf, scale=args.scale)
              for wf in WORKFLOWS}
    for name, cfg in VARIANTS.items():
        per = []
        for wf in WORKFLOWS:
            r = simulate(traces[wf], SizeyMethod(cfg, ttf=1.0), ttf=1.0)
            per.append(r.wastage_gbh)
        row = " | ".join(f"{v:.1f}" for v in per)
        print(f"| {name} | {row} | {sum(per):.1f} |", flush=True)


if __name__ == "__main__":
    main()
