"""Trace-scale engine benchmark: event-loop throughput (tasks/s, events/s)
over an n_tasks x n_nodes grid, plus the full 100k-task / 1k-node ingested
replay (nightly).

    # CI smoke grid + the sample-log ingest cell:
    PYTHONPATH=src python -m benchmarks.engine_bench \
        --out results/fresh/BENCH_engine.json
    # nightly: adds the 100k-task / 1k-node export -> ingest -> replay
    PYTHONPATH=src python -m benchmarks.engine_bench --full

Wall-clock throughputs are artifacts only (CI runners are noisy); the
DETERMINISTIC work counters — events drained (``n_events``), queue entries
examined by placement (``n_scan_entries``), heap insertions
(``n_heap_pushes``) — are pure functions of (trace, config, seed), and
``check_regression.py`` pins them at zero growth: an O(n) scan sneaking
back into the event core fails the gate even on a fast runner.

The sizing method is ``workflow_presets`` (allocation = the preset
constant): zero predictor cost, zero failures, so the measured wall clock
and every counter belong to the ENGINE — event heap, indexed placement,
dependency unlocks — not to sizing arithmetic.

The full mode goes the long way around on purpose — generate, re-stamp a
seeded Poisson arrival process, ``write_jobs_info``, ``read_jobs_info``,
``read_nodes_info``, replay — so the 100k path exercises the ingestion
layer end-to-end, not just the engine.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks._util import dump_json

from repro.baselines import make_method
from repro.data import read_jobs_info, read_nodes_info, write_jobs_info, \
    write_nodes_info
from repro.workflow import generate_workflow, simulate_cluster
from repro.workflow.cluster import NodeSpec

SAMPLE_JOBS = "src/repro/data/sample_traces/sample_jobs_info.txt"
SAMPLE_NODES = "src/repro/data/sample_traces/sample_nodes_info.txt"

# CI smoke grid: (trace scale, node count). mag scale 1.0 ~ 6k tasks.
SMOKE_GRID = ((0.2, 32), (0.2, 256), (1.0, 32), (1.0, 256))


def _cell(label: str, trace, n_nodes: int, wall_s: float, res) -> dict:
    c = res.cluster
    cell = {
        "label": label, "n_tasks": len(trace.tasks), "n_nodes": n_nodes,
        "wall_s": round(wall_s, 3),
        "tasks_per_s": round(len(trace.tasks) / wall_s, 1),
        "events_per_s": round(c.n_events / wall_s, 1),
        "n_events": c.n_events,
        "n_scan_entries": c.n_scan_entries,
        "n_heap_pushes": c.n_heap_pushes,
        "makespan_h": round(c.makespan_h, 4),
        "mean_util": round(c.mean_util, 4),
        "n_aborted": c.n_aborted,
    }
    print(f"engine_bench/{label},n_tasks={cell['n_tasks']},"
          f"n_nodes={n_nodes},wall_s={cell['wall_s']},"
          f"tasks_per_s={cell['tasks_per_s']:.0f},"
          f"events_per_s={cell['events_per_s']:.0f},"
          f"events={cell['n_events']},scans={cell['n_scan_entries']},"
          f"pushes={cell['n_heap_pushes']}")
    return cell


def _replay(trace, n_nodes=None, node_specs=None, node_cap_gb=32.0):
    method = make_method("workflow_presets",
                         machine_cap_gb=trace.machine_cap_gb)
    t0 = time.perf_counter()
    res = simulate_cluster(trace, method, n_nodes=n_nodes or 8,
                           node_cap_gb=node_cap_gb, node_specs=node_specs)
    return time.perf_counter() - t0, res


def _restamp_arrivals(trace, span_h: float, seed: int = 0):
    """Replace arrival times with a seeded Poisson process over ~span_h
    hours (the export drops DAG edges, so EVERY task becomes an arrival —
    this keeps the 100k replay arrival-driven instead of one mega-burst)."""
    gaps = np.random.default_rng(seed).exponential(
        span_h / max(len(trace.tasks), 1), len(trace.tasks))
    arrivals = np.cumsum(gaps)
    tasks = [dataclasses.replace(t, arrival_h=float(a), deps=(), stage=0)
             for t, a in zip(trace.tasks, arrivals)]
    return dataclasses.replace(trace, tasks=tasks)


def run(out_path: str = "BENCH_engine.json", full: bool = False,
        full_scale: float = 17.0, full_nodes: int = 1000) -> dict:
    report: dict = {"method": "workflow_presets", "grid": []}

    for scale, n_nodes in SMOKE_GRID:
        trace = generate_workflow("mag", seed=1, scale=scale,
                                  arrival_rate_per_h=2000.0)
        wall, res = _replay(trace, n_nodes=n_nodes, node_cap_gb=32.0)
        # no dots in labels: check_regression resolves dotted paths
        slabel = f"{scale:g}".replace(".", "p")
        report["grid"].append(
            _cell(f"mag_s{slabel}_n{n_nodes}", trace, n_nodes, wall, res))

    # ingestion smoke cell: the committed sample log on its own node table
    trace = read_jobs_info(SAMPLE_JOBS, time_compress=10.0)
    nodes = read_nodes_info(SAMPLE_NODES)
    wall, res = _replay(trace, node_specs=nodes)
    report["sample_trace"] = _cell("sample_jobs_info", trace, len(nodes),
                                   wall, res)

    if full:
        # 100k-task / 1k-node replay THROUGH the ingestion layer:
        # generate -> re-stamp Poisson arrivals -> write_jobs_info ->
        # read back -> replay on a read-back nodes_info table
        big = _restamp_arrivals(
            generate_workflow("mag", seed=1, scale=full_scale,
                              usage_curves=False),
            span_h=4.0)
        with tempfile.TemporaryDirectory() as d:
            jobs, nodes_f = Path(d) / "jobs.txt", Path(d) / "nodes.txt"
            t0 = time.perf_counter()
            write_jobs_info(big, jobs, mem_unit="mb", time_unit="s")
            write_nodes_info(
                [NodeSpec(f"n{i:04d}", 32.0) for i in range(full_nodes)],
                nodes_f, mem_unit="mb")
            ingested = read_jobs_info(jobs, mem_unit="mb", time_unit="s",
                                      machine_cap_gb=big.machine_cap_gb)
            node_specs = read_nodes_info(nodes_f, mem_unit="mb")
            ingest_s = time.perf_counter() - t0
            wall, res = _replay(ingested, node_specs=node_specs)
        report["full"] = _cell(f"ingested_100k_n{full_nodes}", ingested,
                               full_nodes, wall, res)
        report["full"]["ingest_roundtrip_s"] = round(ingest_s, 3)
        assert len(res.outcomes) == len(ingested.tasks), \
            "full replay dropped tasks"

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="add the 100k-task / 1k-node ingested replay "
                         "(nightly; ~10^2 seconds)")
    ap.add_argument("--full-scale", type=float, default=17.0,
                    help="mag trace scale for the full run (17 ~ 100k tasks)")
    ap.add_argument("--full-nodes", type=int, default=1000)
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()
    run(out_path=args.out, full=args.full, full_scale=args.full_scale,
        full_nodes=args.full_nodes)


if __name__ == "__main__":
    main()
