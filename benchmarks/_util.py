"""Shared benchmark plumbing."""
from __future__ import annotations

import json
import os


def dump_json(out_path: str, doc: dict) -> None:
    """Write a bench report, creating parent directories (CI routes
    fresh outputs into results/fresh/). One place to change the output
    convention for every bench entry point."""
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
