"""Durability benchmark: seeded kill/resume sweep over a journaled
cluster run, measuring what a crash actually costs (PR 6 tentpole,
part 4).

    PYTHONPATH=src python -m benchmarks.durability_bench [--scale 0.05]
                          [--kills 8] [--out BENCH_durability.json]

One complete journaled run (Sizey on the failure-injected event engine)
is the baseline; :mod:`tests.chaos` then kills it at ``--kills`` seeded
byte offsets — step boundaries, mid-step orphan rows and torn final
lines alike — and resumes each cut both ways:

  * ``warm`` (journal replay): repair + snapshot restore + WAL-tail
    replay. Reports the recovery wall time (repair+replay, the restart
    latency a crashed service pays) and the replayed step count, and
    asserts the resumed run's SimResult is *bitwise* the uninterrupted
    one — the headline ``all_warm_resumes_bitwise``.
  * ``cold`` (re-execution): everything running at the crash is
    re-entered through the failure strategy and re-run. Reports the
    re-burned reservation GB·h (``reburn_gbh`` = resumed total waste
    minus baseline; can be negative under checkpoint+temporal, where a
    forced re-entry lands on a tighter sizing) and the makespan stretch.

Gated in ``benchmarks.check_regression``: the bitwise headline (exact),
cold-resume task completion (exact), and warm replay volume (growth-
bounded). Wall times are reported but never gated — CI runners are
noisy.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "tests"))

from benchmarks._util import dump_json
from chaos import (assert_results_equal, kill_at, kill_points,
                   run_journaled)

from repro.baselines.sizey_method import SizeyMethod
from repro.workflow import generate_workflow
from repro.workflow.journal import recover_run

CAP_GB = 64.0
N_COLD = 3          # cold re-execution cells (slower: no replay shortcut)


def _method_factory(path):
    return SizeyMethod(machine_cap_gb=CAP_GB, persist_path=path)


def run(scale: float = 0.05, workflow: str = "eager", kills: int = 8,
        seed: int = 0, out_path: str = "BENCH_durability.json") -> dict:
    trace = generate_workflow(workflow, seed=seed, scale=scale,
                              machine_cap_gb=CAP_GB)
    kw = dict(n_nodes=4, fail_rate_per_node_h=0.05, straggler_rate=0.1,
              fail_seed=seed)
    report: dict = {"workflow": workflow, "scale": scale, "seed": seed,
                    "n_tasks": len(trace.tasks)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "run.jsonl")
        t0 = time.perf_counter()
        baseline = run_journaled(trace, _method_factory, path,
                                 snapshot_every=16, **kw)
        base_wall = time.perf_counter() - t0
        size = os.path.getsize(path)
        report["baseline"] = {
            "tw_gbh": baseline.temporal_wastage_gbh,
            "wastage_gbh": baseline.wastage_gbh,
            "makespan_h": baseline.cluster.makespan_h,
            "journal_bytes": size,
            "wall_s": base_wall,
        }
        cuts = kill_points(path, kills, seed=seed)

        warm_cells, all_bitwise, total_replayed = [], True, 0
        for cut in cuts:
            scratch = kill_at(path, cut, os.path.join(d, "warm.jsonl"))
            t0 = time.perf_counter()
            eng = recover_run(scratch, trace, _method_factory,
                              snapshot_every=16)
            recovery_wall = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = eng.run()
            resume_wall = time.perf_counter() - t0
            try:
                assert_results_equal(baseline, res)
                bitwise = True
            except AssertionError:
                bitwise = False
            all_bitwise &= bitwise
            total_replayed += res.cluster.n_replayed_steps
            warm_cells.append({
                "cut_byte": cut, "cut_frac": cut / size,
                "bitwise": bitwise,
                "replayed_steps": res.cluster.n_replayed_steps,
                "recovery_wall_s": recovery_wall,
                "resume_wall_s": resume_wall,
            })
            print(f"durability_bench/warm,cut={cut},"
                  f"frac={cut / size:.2f},bitwise={bitwise},"
                  f"replayed={res.cluster.n_replayed_steps},"
                  f"recovery_s={recovery_wall:.3f}")

        # cold re-execution: spread N_COLD cells across the cut range
        cold_cells, cold_completed = [], True
        stride = max(1, len(cuts) // N_COLD)
        for cut in cuts[::stride][:N_COLD]:
            scratch = kill_at(path, cut, os.path.join(d, "cold.jsonl"))
            t0 = time.perf_counter()
            eng = recover_run(scratch, trace, _method_factory,
                              resume="cold", snapshot_every=16)
            res = eng.run()
            wall = time.perf_counter() - t0
            completed = (len(res.outcomes) == len(baseline.outcomes)
                         and res.cluster.n_aborted
                         == baseline.cluster.n_aborted)
            cold_completed &= completed
            cold_cells.append({
                "cut_byte": cut, "cut_frac": cut / size,
                "completed": completed,
                "reburn_gbh": res.temporal_wastage_gbh - baseline.temporal_wastage_gbh,
                "makespan_stretch_h": res.cluster.makespan_h
                - baseline.cluster.makespan_h,
                "wall_s": wall,
            })
            print(f"durability_bench/cold,cut={cut},"
                  f"frac={cut / size:.2f},completed={completed},"
                  f"reburn_gbh={res.temporal_wastage_gbh - baseline.temporal_wastage_gbh:.2f}")

    report["warm"] = {
        "cells": warm_cells,
        "total_replayed_steps": total_replayed,
        "mean_recovery_wall_s": sum(c["recovery_wall_s"]
                                    for c in warm_cells) / len(warm_cells),
    }
    report["cold"] = {
        "cells": cold_cells,
        "all_tasks_completed": cold_completed,
        "mean_reburn_gbh": sum(c["reburn_gbh"] for c in cold_cells)
        / len(cold_cells),
    }
    report["headline"] = {
        "all_warm_resumes_bitwise": all_bitwise,
        "n_kill_points": len(cuts),
    }
    print(f"durability_bench/headline,"
          f"all_warm_resumes_bitwise={all_bitwise},"
          f"n_kill_points={len(cuts)},"
          f"total_replayed_steps={total_replayed},"
          f"mean_reburn_gbh={report['cold']['mean_reburn_gbh']:.2f}")

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--workflow", default="eager")
    ap.add_argument("--kills", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_durability.json")
    args = ap.parse_args()
    run(scale=args.scale, workflow=args.workflow, kills=args.kills,
        seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
