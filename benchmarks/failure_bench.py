"""Failure-model benchmark: fail-rate x correlation x failure-strategy
sweep of the cluster engine (Ponder-style comparison, arXiv 2408.00047).

    PYTHONPATH=src python -m benchmarks.failure_bench [--scale 0.05]
                          [--workflow mag] [--out BENCH_failure.json]

Each cell runs Sizey (the crash-aware-capable method) on the event engine
under one failure configuration and reports the waste split by cause —
OOM GB·h (underprediction), interruption GB·h (crash-burned reservation),
and their sum ``failure_waste_gbh``, the axis the strategies compete on:

  * ``correlation=independent`` injects per-node faults at
    ``fail_rate_per_node_h``; ``correlation=rack`` injects whole-rack
    outages at the SAME per-rack rate — the engine draws one schedule per
    rack and each event downs ``n_nodes / n_racks`` nodes, so expected
    node-crashes per hour (``rate x n_nodes``) match the independent
    cells and the comparison isolates the correlation structure; the
    per-node and per-event counting in :class:`ClusterMetrics` keeps the
    two comparable on either axis;
  * strategies: ``retry_same`` (burn + full re-run), ``retry_scaled``
    (re-size through the method before re-dispatch), ``checkpoint``
    (resume from the last checkpoint + crash-aware offset fold);
  * node mixes: a homogeneous 4-node/2-rack set and a heterogeneous
    16/32/64 GB 6-node/2-rack set with a class-labeled trace;
  * one straggler row per mix prices slowdown injection in the same
    trajectory.

Headline (the acceptance contract): ``crash_aware_beats_retry_same`` —
at fail_rate >= 0.05/node·h the checkpoint strategy must beat retry_same
on total failure waste on at least one node mix; ``best_margin_frac``
records by how much.
"""
from __future__ import annotations

import argparse
import time

from benchmarks._util import dump_json

from repro.baselines.sizey_method import SizeyMethod
from repro.core import SizeyConfig
from repro.workflow import generate_workflow, simulate_cluster
from repro.workflow.accounting import FAILURE_STRATEGIES
from repro.workflow.cluster import machine_label, node_specs_from_caps

HETERO_CAPS = (16.0, 32.0, 64.0)
FAIL_RATES = (0.05, 0.2)           # node crashes per node-hour
REPAIR_H = 0.3
RACK_REPAIR_H = 0.5
STRAGGLER_RATE = 0.15


def _cell(mix: str, trace, specs, strategy: str, correlation: str,
          rate: float, ttf: float, seed: int,
          straggler_rate: float = 0.0) -> dict:
    kw: dict = {}
    if correlation == "independent":
        kw["fail_rate_per_node_h"] = rate
        kw["repair_h"] = REPAIR_H
    elif correlation == "rack":
        # the engine draws one exponential schedule PER RACK at this
        # rate, and each event downs n_nodes/n_racks nodes, so expected
        # node-crashes/hour = rate x n_racks x (n_nodes/n_racks) =
        # rate x n_nodes — already the independent cells' intensity.
        # Same rate, different correlation structure: the comparison
        # isolates correlation, not crash volume
        kw["rack_fail_rate_per_h"] = rate
        kw["rack_repair_h"] = RACK_REPAIR_H
    elif correlation != "none":
        raise ValueError(f"unknown correlation {correlation!r}")
    method = SizeyMethod(SizeyConfig(), ttf=ttf, failure_strategy=strategy)
    t0 = time.perf_counter()
    r = simulate_cluster(trace, method, ttf=ttf, node_specs=specs,
                         straggler_rate=straggler_rate, fail_seed=seed,
                         **kw)
    wall = time.perf_counter() - t0
    c = r.cluster
    return {
        "mix": mix, "correlation": correlation, "strategy": strategy,
        "fail_rate": rate, "straggler_rate": straggler_rate,
        "wastage_gbh": r.wastage_gbh,
        "oom_gbh": r.oom_wastage_gbh,
        "interruption_gbh": r.interruption_wastage_gbh,
        "failure_waste_gbh": r.failure_wastage_gbh,
        "makespan_h": c.makespan_h,
        "n_failure_events": c.n_failure_events,
        "n_rack_failures": c.n_rack_failures,
        "n_node_failures": c.n_node_failures,
        "n_interruptions": sum(o.interruptions for o in r.outcomes),
        "n_oom_failures": r.n_failures,
        "n_straggler_attempts": c.n_straggler_attempts,
        "n_aborted": c.n_aborted,
        "wall_s": wall,
    }


def run(scale: float = 0.05, workflow: str = "mag", ttf: float = 1.0,
        seed: int = 0, out_path: str = "BENCH_failure.json") -> dict:
    homo_trace = generate_workflow(workflow, seed=seed, scale=scale)
    hetero_trace = generate_workflow(
        workflow, seed=seed, scale=scale,
        machine_caps_gb={machine_label(c): c for c in HETERO_CAPS})
    mixes = {
        "homogeneous": (homo_trace,
                        node_specs_from_caps([128.0], n_nodes=4, n_racks=2)),
        "hetero_16_32_64": (hetero_trace,
                            node_specs_from_caps(HETERO_CAPS, n_nodes=6,
                                                 n_racks=2)),
    }
    report: dict = {"workflow": workflow, "scale": scale, "ttf": ttf,
                    "fail_rates": list(FAIL_RATES),
                    "n_tasks": len(homo_trace.tasks)}
    cells: list[dict] = []
    for mix, (trace, specs) in mixes.items():
        # failure-free anchor: the pure sizing waste of this mix
        cells.append(_cell(mix, trace, specs, "retry_same", "none", 0.0,
                           ttf, seed))
        for correlation in ("independent", "rack"):
            for rate in FAIL_RATES:
                for strategy in FAILURE_STRATEGIES:
                    cells.append(_cell(mix, trace, specs, strategy,
                                       correlation, rate, ttf, seed))
        # straggler row: slowdown injection priced on the same trajectory
        cells.append(_cell(mix, trace, specs, "retry_same", "none", 0.0,
                           ttf, seed, straggler_rate=STRAGGLER_RATE))
    for c in cells:
        print(f"failure_bench/cell,mix={c['mix']},"
              f"corr={c['correlation']},strategy={c['strategy']},"
              f"rate={c['fail_rate']},straggler={c['straggler_rate']},"
              f"failure_waste_gbh={c['failure_waste_gbh']:.2f},"
              f"oom={c['oom_gbh']:.2f},interr={c['interruption_gbh']:.2f},"
              f"events={c['n_failure_events']},"
              f"makespan_h={c['makespan_h']:.3f}")
    report["cells"] = cells

    # headline: does the crash-aware (checkpoint) strategy beat retry_same
    # on total failure waste at fail_rate >= 0.05 on at least one mix?
    margins = []
    for c in cells:
        if c["strategy"] != "checkpoint" or c["fail_rate"] < 0.05:
            continue
        ref = next(r for r in cells
                   if r["strategy"] == "retry_same"
                   and r["mix"] == c["mix"]
                   and r["correlation"] == c["correlation"]
                   and r["fail_rate"] == c["fail_rate"])
        if ref["failure_waste_gbh"] > 0:
            margins.append({
                "mix": c["mix"], "correlation": c["correlation"],
                "fail_rate": c["fail_rate"],
                "margin_frac": 1.0 - c["failure_waste_gbh"]
                / ref["failure_waste_gbh"],
            })
    best = max((m["margin_frac"] for m in margins), default=0.0)
    report["headline"] = {
        "crash_aware_beats_retry_same": any(m["margin_frac"] > 0.0
                                            for m in margins),
        "best_margin_frac": best,
        "margins": margins,
    }
    print(f"failure_bench/headline,"
          f"crash_aware_beats_retry_same="
          f"{report['headline']['crash_aware_beats_retry_same']},"
          f"best_margin_frac={best:.3f}")

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--workflow", default="mag")
    ap.add_argument("--ttf", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_failure.json")
    args = ap.parse_args()
    run(scale=args.scale, workflow=args.workflow, ttf=args.ttf,
        seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
