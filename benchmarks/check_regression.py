"""CI regression gate: diff freshly produced ``BENCH_*.json`` /
``results/bench_results.json`` against the committed baselines with
per-metric tolerances, so a perf regression FAILS the build instead of
silently shipping in an artifact.

    # in CI: benches write into results/fresh/, then
    PYTHONPATH=src python -m benchmarks.check_regression \
        --fresh-dir results/fresh
    # locally, after an intentional change:
    PYTHONPATH=src python -m benchmarks.check_regression \
        --fresh-dir results/fresh --update-baselines

Rules are per-file, per-metric (dotted paths; ``list[key=value]``
selects an element of a list of dicts):

  * ``min`` / ``max``   — absolute bound on the FRESH value (used for
    contract metrics like the temporal GB·h win, which may not drop
    below 15% whatever the baseline says);
  * ``max_growth`` / ``max_drop`` — relative bound vs the BASELINE value
    (e.g. dispatch counts may not grow: ``max_growth: 0.0``);
  * ``equals``          — exact match on the fresh value (booleans).

Only machine-independent metrics are gated (waste, reductions, event and
dispatch counts, makespans — all deterministic at fixed seed/scale);
wall-clock throughputs (``BENCH_predictor.json``) are tracked as
artifacts but never gated, because CI runners are noisy.

``--update-baselines`` copies every checked fresh file over its baseline
(commit the result) — the explicit, reviewed way to accept a new
performance trajectory.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import shutil
import sys

# file -> list of rules; each rule: {"path": ..., <bound kind>: value}
RULES: dict[str, list[dict]] = {
    "BENCH_temporal.json": [
        # the acceptance contract: temporal win may not drop below 15%
        {"path": "temporal_reduction_vs_peak", "min": 0.15},
        {"path": "cluster.cluster_reduction_vs_peak", "min": 0.05},
        {"path": "serial.sizey_temporal.tw_gbh", "max_growth": 0.10},
        {"path": "serial.sizey.failures", "max_growth": 0.25},
        {"path": "cluster.temporal.n_grow_failures", "max": 10},
        # fused-temporal work bounds (deterministic at fixed seed/scale):
        # full ensemble retrains, cheap refreshes, and change-point sweeps
        # may not grow — a regression here is the 2x-wall bug coming back.
        # wall_ratio itself stays an ungated artifact (runner noise).
        {"path": "counters.full_refits", "max_growth": 0.0},
        {"path": "counters.fused_refreshes", "max_growth": 0.0},
        {"path": "counters.boundary_fits", "max_growth": 0.0},
        {"path": "cluster.temporal.n_resizes", "max_growth": 0.0},
        {"path": "cluster.temporal.n_resize_waves", "max_growth": 0.0},
        {"path": "cluster.temporal.boundary_fits", "max_growth": 0.0},
    ],
    "BENCH_cluster_policies.json": [
        {"path": "frontier[mix=homogeneous,policy=backfill].makespan_h",
         "max_growth": 0.10},
        {"path": "frontier[mix=homogeneous,policy=backfill].wastage_gbh",
         "max_growth": 0.10},
        {"path": "frontier[mix=hetero_16_32_64,policy=best_fit].makespan_h",
         "max_growth": 0.10},
        {"path": "frontier[mix=hetero_16_32_64,policy=best_fit].wastage_gbh",
         "max_growth": 0.10},
        # a matched trace/node-set never admission-rejects
        {"path": "frontier[mix=hetero_16_32_64,policy=best_fit].n_aborted",
         "max": 0},
        # node-count frontier: more nodes may never make the makespan
        # WORSE, and the big-cluster cell's event count is deterministic
        {"path": "node_frontier[n_nodes=32].makespan_h", "max_growth": 0.10},
        {"path": "node_frontier[n_nodes=32].n_events", "max_growth": 0.0},
    ],
    "BENCH_failure.json": [
        # the acceptance contract: crash-aware sizing must keep beating
        # retry_same on total failure waste at fail_rate >= 0.05
        {"path": "headline.crash_aware_beats_retry_same", "equals": True},
        {"path": "headline.best_margin_frac", "min": 0.0},
    ],
    "BENCH_durability.json": [
        # the acceptance contract: EVERY warm (journal-replay) resume
        # must reproduce the uninterrupted SimResult bitwise, whatever
        # byte the kill landed on
        {"path": "headline.all_warm_resumes_bitwise", "equals": True},
        {"path": "headline.n_kill_points", "min": 8},
        # cold re-execution must still finish every task
        {"path": "cold.all_tasks_completed", "equals": True},
        # replay volume and re-burned GB·h are deterministic at fixed
        # seed; bound their growth (wall times stay ungated — CI noise)
        {"path": "warm.total_replayed_steps", "max_growth": 0.25},
        {"path": "cold.mean_reburn_gbh", "max_growth": 0.50},
    ],
    "BENCH_engine.json": [
        # trace-scale engine work counters: pure functions of
        # (trace, config, seed), so ANY growth is an algorithmic
        # regression in the event core (an O(n) rescan sneaking back),
        # not runner noise. Wall/tasks_per_s stay ungated artifacts.
        {"path": "grid[label=mag_s0p2_n32].n_events", "max_growth": 0.0},
        {"path": "grid[label=mag_s0p2_n32].n_scan_entries",
         "max_growth": 0.0},
        {"path": "grid[label=mag_s0p2_n32].n_heap_pushes",
         "max_growth": 0.0},
        {"path": "grid[label=mag_s1_n256].n_events", "max_growth": 0.0},
        {"path": "grid[label=mag_s1_n256].n_scan_entries",
         "max_growth": 0.0},
        {"path": "grid[label=mag_s1_n256].n_heap_pushes",
         "max_growth": 0.0},
        # the ingestion smoke cell: parser + replay must stay lossless
        {"path": "sample_trace.n_tasks", "equals": 99},
        {"path": "sample_trace.n_aborted", "max": 0},
        {"path": "sample_trace.n_events", "max_growth": 0.0},
        {"path": "sample_trace.n_scan_entries", "max_growth": 0.0},
        {"path": "sample_trace.n_heap_pushes", "max_growth": 0.0},
    ],
    "BENCH_obs.json": [
        # the PR 9 contract: telemetry is side-effect-free — every traced
        # run must reproduce the untraced SimResult bitwise (asserted
        # in-bench; recorded here)
        {"path": "headline.traced_equals_untraced", "equals": True},
        # off-mode (no collector installed) engine work counters: the obs
        # layer may not change what the engine does when disabled
        {"path": "engine_overhead[label=mag_s0p2_n32].n_events",
         "max_growth": 0.0},
        {"path": "engine_overhead[label=mag_s0p2_n32].n_scan_entries",
         "max_growth": 0.0},
        {"path": "engine_overhead[label=mag_s0p2_n32].n_heap_pushes",
         "max_growth": 0.0},
        {"path": "engine_overhead[label=mag_s1_n256].n_events",
         "max_growth": 0.0},
        # span counts sit at wave/dispatch granularity (pure functions of
        # trace/config/seed): any growth means an instrumentation site
        # silently moved onto a per-event or per-task path
        {"path": "engine_overhead[label=mag_s1_n256].n_spans",
         "max_growth": 0.0},
        {"path":
         "engine_overhead[label=mag_s1_n256].span_counts.engine/sizing_wave",
         "max_growth": 0.0},
        {"path": "traced_sizey.n_spans", "max_growth": 0.0},
        {"path": "traced_sizey.span_counts.predict", "max_growth": 0.0},
        {"path": "traced_sizey.span_counts.observe", "max_growth": 0.0},
        # off-mode fused device launches, measured under scoped_counters:
        # unchanged by the registry absorption of the legacy globals
        {"path": "traced_sizey.off_counters.predict_pool",
         "max_growth": 0.0},
        {"path": "traced_sizey.off_counters.observe_pool",
         "max_growth": 0.0},
        # exactly one quality row per completed task
        {"path": "traced_sizey.n_quality_samples", "max_growth": 0.0},
    ],
    "BENCH_risk.json": [
        # the acceptance contract: risk-priced Sizey must strictly
        # dominate fixed-offset Sizey on the aggregate waste x
        # failure-rate frontier at matched seeds
        {"path": "headline.risk_dominates_fixed", "equals": True},
        {"path": "aggregate.waste_saved_gbh", "min": 0.0},
        {"path": "aggregate.failures_avoided", "min": 1},
        # a cold risk manager must be bitwise the fixed offset, and warm
        # resumes must regenerate the risk-row stream exactly (both
        # asserted in-bench; recorded here)
        {"path": "headline.risk_off_bitwise", "equals": True},
        {"path": "headline.warm_resume_bitwise", "equals": True},
        {"path": "risk_off.n_risk_rows", "max": 0},
        # deterministic at fixed seed/scale: the chaos cell's risk-row
        # count is a pure function of (trace, config) — any growth means
        # rows leaked onto a replayed path
        {"path": "warm_resume.n_risk_rows", "max_growth": 0.0},
        {"path": "headline.n_cells", "equals": 8},
    ],
    "results/bench_results.json": [
        # decision dispatches may not grow: each cluster ready wave stays
        # ONE fused launch per pool
        {"path": "cluster_bench.sizey.cluster_predict_dispatches",
         "max_growth": 0.0},
        {"path": "cluster_bench.sizey.serial_predict_dispatches",
         "max_growth": 0.0},
        {"path": "cluster_bench.sizey.n_waves", "max_growth": 0.10},
    ],
}

_SEG = re.compile(r"^(?P<key>[^[\]]+)(?:\[(?P<sel>[^\]]+)\])?$")


def resolve(doc, path: str):
    """Walk a dotted path; ``name[k=v,k2=v2]`` selects the unique element
    of a list of dicts matching every (string-compared) key."""
    cur = doc
    for seg in path.split("."):
        m = _SEG.match(seg)
        if m is None:
            raise KeyError(f"bad path segment {seg!r}")
        cur = cur[m.group("key")]
        sel = m.group("sel")
        if sel is not None:
            wants = dict(kv.split("=", 1) for kv in sel.split(","))
            hits = [el for el in cur
                    if all(str(el.get(k)) == v for k, v in wants.items())]
            if len(hits) != 1:
                raise KeyError(f"{seg!r} matched {len(hits)} elements")
            cur = hits[0]
    return cur


def check_file(name: str, fresh_doc, base_doc) -> list[str]:
    """Returns a list of violation messages (empty = pass)."""
    problems = []
    for rule in RULES[name]:
        path = rule["path"]
        try:
            fresh = resolve(fresh_doc, path)
        except (KeyError, TypeError, IndexError) as e:
            problems.append(f"{name}:{path}: missing in fresh output ({e})")
            continue
        if "equals" in rule and fresh != rule["equals"]:
            problems.append(f"{name}:{path}: expected {rule['equals']!r}, "
                            f"got {fresh!r}")
        if "min" in rule and fresh < rule["min"]:
            problems.append(f"{name}:{path}: {fresh:.6g} below the "
                            f"absolute floor {rule['min']:.6g}")
        if "max" in rule and fresh > rule["max"]:
            problems.append(f"{name}:{path}: {fresh:.6g} above the "
                            f"absolute ceiling {rule['max']:.6g}")
        if "max_growth" in rule or "max_drop" in rule:
            try:
                base = resolve(base_doc, path)
            except (KeyError, TypeError, IndexError) as e:
                problems.append(f"{name}:{path}: missing in baseline ({e})")
                continue
            if "max_growth" in rule:
                lim = base * (1.0 + rule["max_growth"])
                if fresh > lim + 1e-12:
                    problems.append(
                        f"{name}:{path}: grew {base:.6g} -> {fresh:.6g} "
                        f"(limit +{rule['max_growth']:.0%} = {lim:.6g})")
            if "max_drop" in rule:
                lim = base * (1.0 - rule["max_drop"])
                if fresh < lim - 1e-12:
                    problems.append(
                        f"{name}:{path}: dropped {base:.6g} -> {fresh:.6g} "
                        f"(limit -{rule['max_drop']:.0%} = {lim:.6g})")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default="results/fresh",
                    help="directory holding the freshly produced bench "
                         "JSONs (flat: results/bench_results.json is "
                         "looked up as bench_results.json here)")
    ap.add_argument("--baseline-dir", default=".",
                    help="repo root holding the committed baselines")
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy every checked fresh file over its baseline "
                         "instead of diffing (then commit the result)")
    ap.add_argument("files", nargs="*",
                    help="subset of baseline files to check (default: "
                         "every file RULES knows)")
    args = ap.parse_args()
    fresh_dir = pathlib.Path(args.fresh_dir)
    base_dir = pathlib.Path(args.baseline_dir)
    names = args.files or sorted(RULES)
    unknown = [n for n in names if n not in RULES]
    if unknown:
        ap.error(f"no rules for {unknown}; known: {sorted(RULES)}")

    failures: list[str] = []
    checked = 0
    for name in names:
        fresh_path = fresh_dir / pathlib.Path(name).name
        base_path = base_dir / name
        if not fresh_path.exists():
            failures.append(f"{name}: fresh output {fresh_path} missing — "
                            f"the bench did not emit its JSON")
            continue
        if args.update_baselines:
            base_path.parent.mkdir(parents=True, exist_ok=True)
            shutil.copyfile(fresh_path, base_path)
            print(f"check_regression: baseline updated {base_path}")
            continue
        if not base_path.exists():
            failures.append(f"{name}: committed baseline {base_path} "
                            f"missing — run --update-baselines and commit")
            continue
        with open(fresh_path) as f:
            fresh_doc = json.load(f)
        with open(base_path) as f:
            base_doc = json.load(f)
        problems = check_file(name, fresh_doc, base_doc)
        checked += 1
        if problems:
            failures.extend(problems)
            print(f"check_regression: FAIL {name}")
        else:
            print(f"check_regression: ok {name} "
                  f"({len(RULES[name])} metrics)")
    if failures:
        print("\ncheck_regression: REGRESSION GATE FAILED", file=sys.stderr)
        for p in failures:
            print(f"  - {p}", file=sys.stderr)
        return 1
    if not args.update_baselines:
        print(f"check_regression: all gates green "
              f"({checked} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
