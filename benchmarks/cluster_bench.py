"""Cluster-engine benchmark: simulated-tasks/sec, decision-dispatch counts,
makespan/utilization of the event-driven engine vs the serial replay, and
the placement-policy x node-mix frontier.

    PYTHONPATH=src python -m benchmarks.cluster_bench [--scale 0.2]
                          [--workflow mag] [--nodes 8]
                          [--policies backfill best_fit spread]
                          [--out BENCH_cluster.json]

Three comparisons:

  * engine overhead — a cheap numpy baseline (witt_lr) through the serial
    replay vs the event engine (same decisions, so the delta is pure
    event-queue/placement cost), reported as simulated tasks/sec;
  * decision dispatches — Sizey serial (one fused device launch per task)
    vs Sizey on the cluster, where each ready wave is sized by one
    ``allocate_batch`` burst (one launch per pool per wave), counted via
    ``repro.core.predictor.DISPATCH_COUNTS``;
  * policy frontier — every requested placement policy on a homogeneous
    and a heterogeneous (16/32/64 GB node classes, class-labeled trace)
    mix: makespan / utilization / wastage / queue delay per cell, so a
    placement-policy regression shows up in the bench trajectory;
  * node-count frontier — utilization/makespan vs cluster size
    (``--node-counts``): where adding nodes stops buying makespan because
    DAG width, not capacity, is the bottleneck.
"""
from __future__ import annotations

import argparse
import time

from benchmarks._util import dump_json

from repro.baselines import make_method
from repro import obs
from repro.baselines.sizey_method import SizeyMethod
from repro.core import SizeyConfig
from repro.core.predictor import DISPATCH_COUNTS
from repro.workflow import (generate_workflow, node_specs_from_caps,
                            simulate, simulate_cluster)
from repro.workflow.cluster import machine_label

HETERO_CAPS = (16.0, 32.0, 64.0)


def run(scale: float = 0.2, workflow: str = "mag", n_nodes: int = 8,
        ttf: float = 1.0, out_path: str = "BENCH_cluster.json",
        policies: tuple[str, ...] = ("backfill", "best_fit", "spread"),
        fail_rate: float = 0.0, frontier_only: bool = False,
        node_counts: tuple[int, ...] = (4, 8, 16, 32)) -> dict:
    """``frontier_only`` skips the engine-overhead and Sizey dispatch
    comparisons — for CI steps that already ran them via
    ``benchmarks.run --smoke`` and only want more frontier cells."""
    trace = generate_workflow(workflow, scale=scale)
    n_tasks = len(trace.tasks)
    n_pools = len({(t.task_type, t.machine) for t in trace.tasks})
    report: dict = {"workflow": workflow, "scale": scale, "n_tasks": n_tasks,
                    "n_pools": n_pools, "n_nodes": n_nodes}

    if frontier_only:
        return _frontier(report, trace, workflow, scale, n_nodes, ttf,
                         policies, fail_rate, out_path,
                         node_counts=node_counts)

    # engine overhead on a cheap method: decisions are numpy, so the wall
    # clock difference is the event queue + placement machinery itself
    t0 = time.perf_counter()
    rs = simulate(trace, make_method("witt_lr"), ttf=ttf)
    serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rc = simulate_cluster(trace, make_method("witt_lr"), ttf=ttf,
                          n_nodes=n_nodes)
    cluster_s = time.perf_counter() - t0
    util = rc.cluster.node_util
    report["engine"] = {
        "serial_tasks_per_s": n_tasks / serial_s,
        "cluster_tasks_per_s": n_tasks / cluster_s,
        "serial_makespan_h": rs.total_runtime_h,
        "cluster_makespan_h": rc.cluster.makespan_h,
        "makespan_speedup": rs.total_runtime_h
        / max(rc.cluster.makespan_h, 1e-12),
        "mean_node_util": sum(util.values()) / max(len(util), 1),
        "peak_reserved_gb": rc.cluster.peak_reserved_gb,
        "mean_queue_delay_h": rc.cluster.mean_queue_delay_h,
        "n_waves": rc.cluster.n_waves,
    }
    print(f"cluster_bench/engine,serial_tasks_per_s="
          f"{report['engine']['serial_tasks_per_s']:.0f},"
          f"cluster_tasks_per_s={report['engine']['cluster_tasks_per_s']:.0f},"
          f"makespan_speedup={report['engine']['makespan_speedup']:.2f}x,"
          f"mean_util={report['engine']['mean_node_util']:.2f}")

    # decision dispatches: serial per-task vs per-(wave x pool) bursts
    with obs.scoped_counters(DISPATCH_COUNTS) as dc:
        t0 = time.perf_counter()
        simulate(trace, SizeyMethod(SizeyConfig(), ttf=ttf), ttf=ttf)
        sizey_serial_s = time.perf_counter() - t0
        serial_dispatches = dc["predict_pool"]

    with obs.scoped_counters(DISPATCH_COUNTS) as dc:
        t0 = time.perf_counter()
        rz = simulate_cluster(trace, SizeyMethod(SizeyConfig(), ttf=ttf),
                              ttf=ttf, n_nodes=n_nodes)
        sizey_cluster_s = time.perf_counter() - t0
        cluster_dispatches = dc["predict_pool"]
    report["sizey"] = {
        "serial_s": sizey_serial_s,
        "cluster_s": sizey_cluster_s,
        "serial_tasks_per_s": n_tasks / sizey_serial_s,
        "cluster_tasks_per_s": n_tasks / sizey_cluster_s,
        "serial_predict_dispatches": serial_dispatches,
        "cluster_predict_dispatches": cluster_dispatches,
        "dispatch_bound_waves_x_pools": rz.cluster.n_waves * n_pools,
        "n_waves": rz.cluster.n_waves,
        "dispatch_reduction": serial_dispatches
        / max(cluster_dispatches, 1),
    }
    print(f"cluster_bench/sizey,serial_dispatches={serial_dispatches},"
          f"cluster_dispatches={cluster_dispatches},"
          f"waves={rz.cluster.n_waves},"
          f"bound={report['sizey']['dispatch_bound_waves_x_pools']},"
          f"dispatch_reduction={report['sizey']['dispatch_reduction']:.1f}x,"
          f"cluster_tasks_per_s="
          f"{report['sizey']['cluster_tasks_per_s']:.0f}")

    return _frontier(report, trace, workflow, scale, n_nodes, ttf, policies,
                     fail_rate, out_path, node_counts=node_counts)


def _frontier(report: dict, trace, workflow: str, scale: float, n_nodes: int,
              ttf: float, policies: tuple[str, ...], fail_rate: float,
              out_path: str,
              node_counts: tuple[int, ...] = (4, 8, 16, 32)) -> dict:
    # placement-policy x node-mix frontier (cheap numpy method: the cells
    # compare placement, not sizing)
    hetero_trace = generate_workflow(
        workflow, scale=scale,
        machine_caps_gb={machine_label(c): c for c in HETERO_CAPS})
    mixes = {
        "homogeneous": (trace, None),
        "hetero_16_32_64": (hetero_trace,
                            node_specs_from_caps(HETERO_CAPS,
                                                 n_nodes=n_nodes)),
    }
    frontier = []
    for mix, (mtrace, specs) in mixes.items():
        for pol in policies:
            t0 = time.perf_counter()
            rf = simulate_cluster(mtrace, make_method("witt_lr"), ttf=ttf,
                                  n_nodes=n_nodes, node_specs=specs,
                                  policy=pol,
                                  fail_rate_per_node_h=fail_rate)
            wall = time.perf_counter() - t0
            c = rf.cluster
            cell = {
                "mix": mix, "policy": pol,
                "makespan_h": c.makespan_h,
                # capacity-weighted: a busy 64 GB node counts 4x a 16 GB one
                "mean_util": c.mean_util,
                "class_util": c.class_util,
                "wastage_gbh": rf.wastage_gbh,
                "mean_queue_delay_h": c.mean_queue_delay_h,
                "n_aborted": c.n_aborted,
                "n_preemptions": c.n_preemptions,
                "tasks_per_s": len(mtrace.tasks) / wall,
            }
            frontier.append(cell)
            print(f"cluster_bench/frontier,mix={mix},policy={pol},"
                  f"makespan_h={cell['makespan_h']:.3f},"
                  f"mean_util={cell['mean_util']:.3f},"
                  f"wastage_gbh={cell['wastage_gbh']:.1f},"
                  f"queue_delay_h={cell['mean_queue_delay_h']:.4f},"
                  f"aborted={cell['n_aborted']}")
    report["frontier"] = frontier

    # utilization/makespan frontier vs NODE COUNT (homogeneous, backfill):
    # where adding nodes stops buying makespan because the workload's DAG
    # width — not capacity — is the bottleneck. Cheap with the indexed
    # event core, so it runs in every CI smoke.
    node_frontier = []
    for nn in node_counts:
        t0 = time.perf_counter()
        rn = simulate_cluster(trace, make_method("witt_lr"), ttf=ttf,
                              n_nodes=nn, policy="backfill")
        wall = time.perf_counter() - t0
        c = rn.cluster
        cell = {
            "n_nodes": nn,
            "makespan_h": c.makespan_h,
            "mean_util": c.mean_util,
            "mean_queue_delay_h": c.mean_queue_delay_h,
            "peak_reserved_gb": c.peak_reserved_gb,
            "n_events": c.n_events,
            "tasks_per_s": len(trace.tasks) / wall,
        }
        node_frontier.append(cell)
        print(f"cluster_bench/node_frontier,n_nodes={nn},"
              f"makespan_h={cell['makespan_h']:.3f},"
              f"mean_util={cell['mean_util']:.3f},"
              f"queue_delay_h={cell['mean_queue_delay_h']:.4f},"
              f"tasks_per_s={cell['tasks_per_s']:.0f}")
    report["node_frontier"] = node_frontier

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--workflow", default="mag")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--ttf", type=float, default=1.0)
    ap.add_argument("--policies", nargs="+",
                    default=["backfill", "best_fit", "spread"])
    ap.add_argument("--fail-rate", type=float, default=0.0,
                    help="injected node crashes per node-hour (frontier)")
    ap.add_argument("--frontier-only", action="store_true",
                    help="skip the engine/Sizey comparisons (CI runs them "
                         "via benchmarks.run --smoke already)")
    ap.add_argument("--node-counts", type=int, nargs="+",
                    default=[4, 8, 16, 32], metavar="N",
                    help="node counts for the utilization/makespan-vs-"
                         "node-count frontier (homogeneous, backfill)")
    ap.add_argument("--out", default="BENCH_cluster.json")
    args = ap.parse_args()
    run(scale=args.scale, workflow=args.workflow, n_nodes=args.nodes,
        ttf=args.ttf, out_path=args.out, policies=tuple(args.policies),
        fail_rate=args.fail_rate, frontier_only=args.frontier_only,
        node_counts=tuple(args.node_counts))


if __name__ == "__main__":
    main()
