"""Roofline table from the dry-run artifacts (assignment deliverable g).

Reads results/dryrun.jsonl (written by repro.launch.dryrun) and renders
the per-(arch x shape x mesh) three-term roofline with bottleneck calls
and useful-compute ratios. Markdown output feeds EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import os

DEFAULT_PATH = os.environ.get("REPRO_DRYRUN_RESULTS", "results/dryrun.jsonl")

COLUMNS = ("arch", "shape", "mesh", "chips", "peak_gb", "compute_s",
           "memory_s", "collective_s", "bottleneck", "useful", "frac")


def load_rows(path: str = DEFAULT_PATH) -> list[dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    # keep only the LAST row per cell (later runs supersede earlier ones)
    by_key = {}
    for line in open(path):
        r = json.loads(line)
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    for r in by_key.values():
        if r["status"] == "ok":
            rt = r["roofline"]
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "chips": r["chips"],
                "peak_gb": r["memory"]["peak_gb"],
                "compute_s": rt["compute_s"], "memory_s": rt["memory_s"],
                "collective_s": rt["collective_s"],
                "bottleneck": rt["bottleneck"],
                "useful": rt["useful_ratio"],
                "frac": rt["roofline_fraction"],
            })
        elif r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "skipped": r["reason"]})
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda x: (x["arch"], order.get(x["shape"], 9),
                             x["mesh"]))
    return rows


def markdown_table(rows: list[dict]) -> str:
    lines = ["| arch | shape | mesh | chips | peak GB/chip | compute s | "
             "memory s | collective s | bottleneck | useful | frac |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                         f"SKIP | - | - | - | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['peak_gb']:.2f} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful']:.3f} | {r['frac']:.3f} |")
    return "\n".join(lines)


def csv_rows(rows: list[dict]):
    for r in rows:
        if "skipped" in r:
            continue
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        yield (f"{name},bottleneck={r['bottleneck']},"
               f"compute_s={r['compute_s']:.4e},memory_s={r['memory_s']:.4e},"
               f"collective_s={r['collective_s']:.4e},"
               f"useful={r['useful']:.4f},frac={r['frac']:.4f},"
               f"peak_gb={r['peak_gb']:.2f}")


def main(path: str = DEFAULT_PATH):
    rows = load_rows(path)
    if not rows:
        print(f"roofline: no dry-run rows at {path} "
              "(run python -m repro.launch.dryrun)")
        return
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
