"""Temporal-subsystem benchmark: time-integrated (GB·h) waste of temporal
vs peak-based allocators on ramp-shaped traces, and the cluster engine's
resize-event overhead.

    PYTHONPATH=src python -m benchmarks.temporal_bench [--scale 0.1]
                          [--workflow mag] [--k 4] [--nodes 4]
                          [--out BENCH_temporal.json]

Three comparisons:

  * serial waste — peak Sizey vs temporal Sizey (k segments) vs the KS+
    baseline vs user presets on a ramp-curve trace (every task type ramps
    memory over its runtime — the workload where a constant peak
    reservation over-reserves most). Headline:
    ``temporal_reduction_vs_peak`` of time-integrated GB·h waste, which
    the acceptance criteria require to be positive;
  * cluster resizing — the same workload (Poisson root arrivals, so the
    predictor has history before whole-type waves hit) through the event
    engine with RESIZE events live: waste, resize/grow-failure counts,
    makespan;
  * resize overhead — wall-clock of the temporal cluster run vs the peak
    cluster run (the delta prices the extra events + plan bookkeeping),
    plus events-per-second.
"""
from __future__ import annotations

import argparse
import time

from benchmarks._util import dump_json

from repro.baselines import make_method
from repro.baselines.sizey_method import SizeyMethod
from repro.core import SizeyConfig
from repro.workflow import generate_workflow, simulate, simulate_cluster

METHODS = ("sizey", "sizey_temporal", "ks_plus", "workflow_presets")


def _method(name: str, ttf: float, k: int):
    if name == "sizey":
        return SizeyMethod(SizeyConfig(), ttf=ttf)
    if name == "sizey_temporal":
        return SizeyMethod(SizeyConfig(), ttf=ttf, temporal_k=k)
    if name == "ks_plus":
        return make_method("ks_plus", ttf=ttf, k_segments=k)
    return make_method(name, ttf=ttf)


def run(scale: float = 0.1, workflow: str = "mag", k: int = 4,
        n_nodes: int = 4, ttf: float = 1.0, seed: int = 0,
        out_path: str = "BENCH_temporal.json") -> dict:
    trace = generate_workflow(workflow, seed=seed, scale=scale,
                              curve_shapes=("ramp",))
    report: dict = {"workflow": workflow, "scale": scale, "k_segments": k,
                    "n_tasks": len(trace.tasks), "ttf": ttf,
                    "n_nodes": n_nodes}

    # ---------------------------------------------------- serial waste
    serial = {}
    for name in METHODS:
        t0 = time.perf_counter()
        r = simulate(trace, _method(name, ttf, k), ttf=ttf)
        serial[name] = {
            "tw_gbh": r.temporal_wastage_gbh,
            "wastage_gbh": r.wastage_gbh,
            "failures": r.n_failures,
            "wall_s": time.perf_counter() - t0,
        }
        print(f"temporal_bench/serial,method={name},"
              f"tw_gbh={serial[name]['tw_gbh']:.1f},"
              f"wastage_gbh={serial[name]['wastage_gbh']:.1f},"
              f"failures={serial[name]['failures']}")
    report["serial"] = serial
    reduction = 1.0 - (serial["sizey_temporal"]["tw_gbh"]
                       / max(serial["sizey"]["tw_gbh"], 1e-12))
    report["temporal_reduction_vs_peak"] = reduction
    print(f"temporal_bench/headline,"
          f"temporal_reduction_vs_peak={reduction:.3f}")

    # ------------------------------------------------- cluster + overhead
    # Poisson root arrivals stagger the first wave of each task type:
    # without them the whole stage-0 population is sized in one all-preset
    # burst (no history yet) and preset waste swamps BOTH allocators
    ctrace = generate_workflow(workflow, seed=seed, scale=scale,
                               curve_shapes=("ramp",),
                               arrival_rate_per_h=30.0)
    t0 = time.perf_counter()
    rp = simulate_cluster(ctrace, _method("sizey", ttf, k), ttf=ttf,
                          n_nodes=n_nodes)
    peak_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    rt = simulate_cluster(ctrace, _method("sizey_temporal", ttf, k), ttf=ttf,
                          n_nodes=n_nodes)
    temp_wall = time.perf_counter() - t0
    c = rt.cluster
    report["cluster"] = {
        "peak": {"tw_gbh": rp.temporal_wastage_gbh,
                 "makespan_h": rp.cluster.makespan_h,
                 "mean_util": rp.cluster.mean_util,
                 "wall_s": peak_wall},
        "temporal": {"tw_gbh": rt.temporal_wastage_gbh,
                     "makespan_h": c.makespan_h,
                     "mean_util": c.mean_util,
                     "n_resizes": c.n_resizes,
                     "n_grow_failures": c.n_grow_failures,
                     "wall_s": temp_wall},
        # the resize machinery's price: extra wall per successful resize
        "resize_overhead_s": temp_wall - peak_wall,
        "resizes_per_s": c.n_resizes / max(temp_wall, 1e-12),
        "cluster_reduction_vs_peak":
            1.0 - rt.temporal_wastage_gbh
            / max(rp.temporal_wastage_gbh, 1e-12),
    }
    print(f"temporal_bench/cluster,"
          f"peak_tw={rp.temporal_wastage_gbh:.1f},"
          f"temporal_tw={rt.temporal_wastage_gbh:.1f},"
          f"n_resizes={c.n_resizes},n_grow_failures={c.n_grow_failures},"
          f"overhead_s={report['cluster']['resize_overhead_s']:.2f}")

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--workflow", default="mag")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--ttf", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_temporal.json")
    args = ap.parse_args()
    run(scale=args.scale, workflow=args.workflow, k=args.k,
        n_nodes=args.nodes, ttf=args.ttf, seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
