"""Temporal-subsystem benchmark: time-integrated (GB·h) waste of temporal
vs peak-based allocators on ramp-shaped traces, the temporal/peak
wall-clock ratio, and the cluster engine's resize-event overhead.

    PYTHONPATH=src python -m benchmarks.temporal_bench [--scale 0.1]
                          [--workflow mag] [--k 4] [--nodes 4]
                          [--out BENCH_temporal.json]

Three comparisons:

  * serial waste — peak Sizey vs temporal Sizey (k segments) vs the KS+
    baseline vs user presets on a ramp-curve trace (every task type ramps
    memory over its runtime — the workload where a constant peak
    reservation over-reserves most). Headline:
    ``temporal_reduction_vs_peak`` of time-integrated GB·h waste, which
    the acceptance criteria require to be positive;
  * temporal cost — the two fused methods run TWICE: the first pass pays
    the one-off XLA compiles (recorded as ``serial_cold.*`` artifacts),
    the second measures the steady-state wall the jit cache makes
    representative of any longer run. ``wall_ratio`` (steady temporal /
    steady peak) is the headline the fused temporal path keeps <= 1.2x;
    the deterministic work counters behind it (full refits, fused
    refreshes, boundary fits/hits) land in ``counters`` and are gated at
    zero growth in CI — wall-clock itself stays an ungated artifact
    (runners are noisy);
  * cluster resizing — the same workload (Poisson root arrivals, so the
    predictor has history before whole-type waves hit) through the event
    engine with RESIZE events live: waste, resize/wave/grow-failure
    counts, makespan, and the temporal-vs-peak cluster wall ratio (jit
    already warm from the serial section).
"""
from __future__ import annotations

import argparse
import time

from benchmarks._util import dump_json

from repro import obs
from repro.baselines import make_method
from repro.baselines.sizey_method import SizeyMethod
from repro.core import SizeyConfig
from repro.core.predictor import DISPATCH_COUNTS
from repro.core.temporal.predictor import BOUNDARY_COUNTS
from repro.workflow import generate_workflow, simulate, simulate_cluster

METHODS = ("sizey", "sizey_temporal", "ks_plus", "workflow_presets")
FUSED = ("sizey", "sizey_temporal")


def _method(name: str, ttf: float, k: int):
    if name == "sizey":
        return SizeyMethod(SizeyConfig(), ttf=ttf)
    if name == "sizey_temporal":
        return SizeyMethod(SizeyConfig(), ttf=ttf, temporal_k=k)
    if name == "ks_plus":
        return make_method("ks_plus", ttf=ttf, k_segments=k)
    return make_method(name, ttf=ttf)


def run(scale: float = 0.1, workflow: str = "mag", k: int = 4,
        n_nodes: int = 4, ttf: float = 1.0, seed: int = 0,
        out_path: str = "BENCH_temporal.json") -> dict:
    trace = generate_workflow(workflow, seed=seed, scale=scale,
                              curve_shapes=("ramp",))
    report: dict = {"workflow": workflow, "scale": scale, "k_segments": k,
                    "n_tasks": len(trace.tasks), "ttf": ttf,
                    "n_nodes": n_nodes}

    # ---------------------------------------------------- serial waste
    # cold pass: first run of each fused method pays the XLA compiles
    # (artifact only; the jitted programs are cached process-wide per
    # frozen config, so the timed pass below is the steady state)
    cold = {}
    for name in FUSED:
        t0 = time.perf_counter()
        simulate(trace, _method(name, ttf, k), ttf=ttf)
        cold[name] = {"wall_s": time.perf_counter() - t0}
    report["serial_cold"] = cold
    report["serial_cold"]["wall_ratio"] = (
        cold["sizey_temporal"]["wall_s"] / max(cold["sizey"]["wall_s"],
                                               1e-12))

    serial = {}
    counters = {}
    for name in METHODS:
        with obs.scoped_counters(DISPATCH_COUNTS,
                                 BOUNDARY_COUNTS) as (dc, bc):
            t0 = time.perf_counter()
            r = simulate(trace, _method(name, ttf, k), ttf=ttf)
            wall = time.perf_counter() - t0
            if name == "sizey_temporal":
                # deterministic work counters of the warm temporal run:
                # the amortized-refit schedule and the generation-keyed
                # boundary cache make all of these fixed at fixed
                # seed/scale
                counters = {
                    "full_refits": dc["observe_pool"],
                    "fused_refreshes": dc["refresh_pool"],
                    "boundary_fits": bc["fit"],
                    "boundary_hits": bc["hit"],
                }
        serial[name] = {
            "tw_gbh": r.temporal_wastage_gbh,
            "wastage_gbh": r.wastage_gbh,
            "failures": r.n_failures,
            "wall_s": wall,
        }
        print(f"temporal_bench/serial,method={name},"
              f"tw_gbh={serial[name]['tw_gbh']:.1f},"
              f"wastage_gbh={serial[name]['wastage_gbh']:.1f},"
              f"failures={serial[name]['failures']},wall_s={wall:.2f}")
    report["serial"] = serial
    report["counters"] = counters
    reduction = 1.0 - (serial["sizey_temporal"]["tw_gbh"]
                       / max(serial["sizey"]["tw_gbh"], 1e-12))
    report["temporal_reduction_vs_peak"] = reduction
    wall_ratio = (serial["sizey_temporal"]["wall_s"]
                  / max(serial["sizey"]["wall_s"], 1e-12))
    report["wall_ratio"] = wall_ratio
    print(f"temporal_bench/headline,"
          f"temporal_reduction_vs_peak={reduction:.3f},"
          f"wall_ratio={wall_ratio:.2f},"
          f"full_refits={counters['full_refits']},"
          f"fused_refreshes={counters['fused_refreshes']},"
          f"boundary_fits={counters['boundary_fits']},"
          f"boundary_hits={counters['boundary_hits']}")

    # ------------------------------------------------- cluster + overhead
    # Poisson root arrivals stagger the first wave of each task type:
    # without them the whole stage-0 population is sized in one all-preset
    # burst (no history yet) and preset waste swamps BOTH allocators
    ctrace = generate_workflow(workflow, seed=seed, scale=scale,
                               curve_shapes=("ramp",),
                               arrival_rate_per_h=30.0)
    t0 = time.perf_counter()
    rp = simulate_cluster(ctrace, _method("sizey", ttf, k), ttf=ttf,
                          n_nodes=n_nodes)
    peak_wall = time.perf_counter() - t0
    with obs.scoped_counters(BOUNDARY_COUNTS) as bc:
        t0 = time.perf_counter()
        rt = simulate_cluster(ctrace, _method("sizey_temporal", ttf, k),
                              ttf=ttf, n_nodes=n_nodes)
        temp_wall = time.perf_counter() - t0
        # scheduling waves ask for every member's boundaries but a pool
        # only refits once per completion generation — the hit count is
        # the cache doing its job (deterministic, gated alongside the
        # resize counters)
        cluster_bounds = {"boundary_fits": bc["fit"],
                          "boundary_hits": bc["hit"]}
    c = rt.cluster
    report["cluster"] = {
        "peak": {"tw_gbh": rp.temporal_wastage_gbh,
                 "makespan_h": rp.cluster.makespan_h,
                 "mean_util": rp.cluster.mean_util,
                 "wall_s": peak_wall},
        "temporal": {"tw_gbh": rt.temporal_wastage_gbh,
                     "makespan_h": c.makespan_h,
                     "mean_util": c.mean_util,
                     "n_resizes": c.n_resizes,
                     "n_resize_waves": c.n_resize_waves,
                     "n_grow_failures": c.n_grow_failures,
                     "wall_s": temp_wall, **cluster_bounds},
        # the resize machinery's price: extra wall per successful resize
        "resize_overhead_s": temp_wall - peak_wall,
        "resizes_per_s": c.n_resizes / max(temp_wall, 1e-12),
        "wall_ratio": temp_wall / max(peak_wall, 1e-12),
        "cluster_reduction_vs_peak":
            1.0 - rt.temporal_wastage_gbh
            / max(rp.temporal_wastage_gbh, 1e-12),
    }
    print(f"temporal_bench/cluster,"
          f"peak_tw={rp.temporal_wastage_gbh:.1f},"
          f"temporal_tw={rt.temporal_wastage_gbh:.1f},"
          f"n_resizes={c.n_resizes},n_resize_waves={c.n_resize_waves},"
          f"n_grow_failures={c.n_grow_failures},"
          f"overhead_s={report['cluster']['resize_overhead_s']:.2f},"
          f"wall_ratio={report['cluster']['wall_ratio']:.2f}")

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--workflow", default="mag")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--ttf", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_temporal.json")
    args = ap.parse_args()
    run(scale=args.scale, workflow=args.workflow, k=args.k,
        n_nodes=args.nodes, ttf=args.ttf, seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
