"""Risk-priced sizing benchmark: waste x failure-rate frontier of
``SizeyMethod(risk=True)`` vs fixed-offset Sizey at matched seeds, plus
the two bitwise contracts the risk layer must keep.

    PYTHONPATH=src python -m benchmarks.risk_bench \
        --out results/fresh/BENCH_risk.json

Three claims are checked, mirroring the PR 10 contract:

  * **Risk pricing dominates the fixed offset on the frontier.** Over a
    matched-seed grid (workflow x seed x injected fail-rate, identical
    traces, node counts and crash seeds for both methods), the
    risk-priced runs must waste strictly fewer GB*h in aggregate AND
    fail strictly fewer times in aggregate
    (``headline.risk_dominates_fixed``). Per-cell Pareto verdicts ride
    in ``frontier[*].pareto`` — individual cells may trade one axis for
    the other (a generous band buys fewer OOMs for a little waste), but
    the aggregate must win both.
  * **risk=off is bitwise PR 9.** A cold-configured risk manager
    (``min_samples`` beyond any pool) never engages, so its run must
    reproduce the plain fixed-offset SimResult bitwise with zero risk
    rows emitted (``headline.risk_off_bitwise``).
  * **Warm resumes stay bitwise under the aux rows.** A journaled crashy
    run killed at a byte offset and resumed must reproduce both the
    SimResult and the full risk-row stream (chosen quantile + band
    width) bitwise (``headline.warm_resume_bitwise``).

All metrics are pure functions of (trace, config, seed) — deterministic,
so ``check_regression.py`` gates the headline booleans exactly and the
aggregate margins as absolute floors.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks._util import dump_json

from repro.baselines.sizey_method import SizeyMethod
from repro.core.risk import RiskConfig
from repro.obs.risk import read_risk_rows
from repro.workflow import generate_workflow, simulate_cluster

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "tests"))
from chaos import (assert_results_equal, kill_and_resume, kill_points,  # noqa: E402
                   run_journaled)

# matched-seed frontier grid: (workflow, seed, injected fail rate /node/h)
GRID = tuple((wf, seed, fr)
             for wf in ("eager", "mag")
             for seed in (1, 2)
             for fr in (0.0, 0.05))
SCALE = 0.2
N_NODES = 12

# the chaos cell: small + crashy, but pools still outgrow min_history
CHAOS_SCALE = 0.15
CHAOS_RISK = RiskConfig(min_samples=2, window=64)


def _cell(method, trace, seed: int, fr: float) -> dict:
    res = simulate_cluster(trace, method, n_nodes=N_NODES,
                           fail_rate_per_node_h=fr, fail_seed=seed)
    return {
        "wastage_gbh": round(sum(o.wastage_gbh for o in res.outcomes), 3),
        "failures": sum(o.failures for o in res.outcomes),
        "makespan_h": round(res.makespan_h, 4),
    }


def _pareto(fixed: dict, risk: dict) -> str:
    dw = risk["wastage_gbh"] - fixed["wastage_gbh"]
    df = risk["failures"] - fixed["failures"]
    if dw == 0 and df == 0:
        return "tie"
    if dw <= 0 and df <= 0:
        return "dominates"
    if dw >= 0 and df >= 0:
        return "dominated"
    return "trade"


def run(out_path: str = "BENCH_risk.json") -> dict:
    report: dict = {"frontier": []}

    # ---------------------------------------------------------- frontier
    for wf, seed, fr in GRID:
        t0 = time.perf_counter()
        trace = generate_workflow(wf, seed=seed, scale=SCALE)
        cap = trace.machine_cap_gb
        fixed = _cell(SizeyMethod(machine_cap_gb=cap), trace, seed, fr)
        risk = _cell(SizeyMethod(machine_cap_gb=cap, risk=True),
                     trace, seed, fr)
        cell = {"workflow": wf, "seed": seed, "fail_rate": fr,
                "n_tasks": len(trace.tasks), "fixed": fixed, "risk": risk,
                "pareto": _pareto(fixed, risk)}
        report["frontier"].append(cell)
        print(f"risk_bench/{wf}_s{seed}_fr{fr:g}: "
              f"fixed waste={fixed['wastage_gbh']:.0f} "
              f"fails={fixed['failures']} | "
              f"risk waste={risk['wastage_gbh']:.0f} "
              f"fails={risk['failures']} "
              f"[{cell['pareto']}] ({time.perf_counter() - t0:.0f}s)",
              flush=True)

    agg = {
        "fixed_wastage_gbh": round(sum(c["fixed"]["wastage_gbh"]
                                       for c in report["frontier"]), 3),
        "risk_wastage_gbh": round(sum(c["risk"]["wastage_gbh"]
                                      for c in report["frontier"]), 3),
        "fixed_failures": sum(c["fixed"]["failures"]
                              for c in report["frontier"]),
        "risk_failures": sum(c["risk"]["failures"]
                             for c in report["frontier"]),
    }
    agg["waste_saved_gbh"] = round(
        agg["fixed_wastage_gbh"] - agg["risk_wastage_gbh"], 3)
    agg["failures_avoided"] = agg["fixed_failures"] - agg["risk_failures"]
    agg["n_cells_dominating"] = sum(
        c["pareto"] == "dominates" for c in report["frontier"])
    agg["n_cells_dominated"] = sum(
        c["pareto"] == "dominated" for c in report["frontier"])
    report["aggregate"] = agg
    dominates = agg["waste_saved_gbh"] > 0 and agg["failures_avoided"] > 0
    print(f"risk_bench/aggregate: waste_saved={agg['waste_saved_gbh']:.1f} "
          f"failures_avoided={agg['failures_avoided']} "
          f"dominates={dominates}", flush=True)

    # ----------------------------------------------------- risk=off bitwise
    trace = generate_workflow("eager", seed=1, scale=SCALE)
    cap = trace.machine_cap_gb
    base = simulate_cluster(trace, SizeyMethod(machine_cap_gb=cap),
                            n_nodes=N_NODES)
    cold_method = SizeyMethod(
        machine_cap_gb=cap,
        risk=RiskConfig(min_samples=10 ** 9, window=10 ** 9))
    cold = simulate_cluster(trace, cold_method, n_nodes=N_NODES)
    assert_results_equal(base, cold)
    n_cold_rows = len(read_risk_rows(cold_method.predictor.db))
    assert n_cold_rows == 0, f"cold risk emitted {n_cold_rows} rows"
    report["risk_off"] = {"bitwise": True, "n_risk_rows": n_cold_rows}
    print("risk_bench/risk_off: bitwise=True", flush=True)

    # ------------------------------------------------- warm resume bitwise
    import tempfile
    trace = generate_workflow("eager", seed=5, scale=CHAOS_SCALE,
                              machine_cap_gb=64.0)

    def factory(path):
        return SizeyMethod(machine_cap_gb=64.0, persist_path=path,
                           risk=CHAOS_RISK, failure_strategy="auto")

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "run.jsonl")
        kw = dict(n_nodes=4, fail_rate_per_node_h=0.1, fail_seed=5)
        baseline = run_journaled(trace, factory, path, **kw)
        base_rows = read_risk_rows(path)
        assert base_rows, "chaos cell emitted no risk rows"
        cuts = kill_points(path, 2, seed=5)
        for cut in cuts:
            res, _eng = kill_and_resume(path, cut, trace, factory)
            assert_results_equal(baseline, res)
            got = read_risk_rows(path + f".cut{cut}")
            assert got == base_rows, f"kill@{cut}: risk rows diverged"
    report["warm_resume"] = {"bitwise": True, "n_kill_points": len(cuts),
                             "n_risk_rows": len(base_rows)}
    print(f"risk_bench/warm_resume: bitwise=True "
          f"kill_points={len(cuts)} risk_rows={len(base_rows)}", flush=True)

    report["headline"] = {
        "risk_dominates_fixed": dominates,
        "risk_off_bitwise": True,
        "warm_resume_bitwise": True,
        "n_cells": len(report["frontier"]),
    }

    if out_path:
        dump_json(out_path, report)
        print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_risk.json")
    args = ap.parse_args()
    run(out_path=args.out)


if __name__ == "__main__":
    main()
